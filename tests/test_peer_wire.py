"""Peer data plane: direct worker-to-worker wire transfers.

Unit level: DataServer/PeerWireClient protocol on both transports --
round trips (raw + compressed), connection-pool reuse and bounds,
mid-transfer aborts, close-wakes-blocked-peers, invalidation.
Integration level (slow): a real process cluster resolves cross-worker
dependencies over the wire, and killing the serving worker mid-flight
completes the task via store fallback / lineage recovery -- no hang, no
torn bytes.
"""

from __future__ import annotations

import threading
import time
import uuid

import numpy as np
import pytest

from repro.core.compress import LINK_PEER, TransferLedger
from repro.core.serialize import FrameBundle, deserialize, serialize
from repro.runtime.dataserver import DataServer, PeerWireClient
from repro.runtime.transfer import BlobCache, SpillCache


def _inproc_addr() -> str:
    return f"inproc://pw-{uuid.uuid4().hex[:8]}"


@pytest.fixture(params=["inproc", "tcp"])
def address(request):
    if request.param == "tcp":
        return "tcp://127.0.0.1:0"
    return _inproc_addr()


def _served_cache(payload: bytes, key: str = "k") -> BlobCache:
    cache = BlobCache(max_bytes=4 * len(payload) + 1024)
    cache.put(key, FrameBundle([memoryview(payload)]))
    return cache


# ---------------------------------------------------------------------------
# round trips


def test_fetch_roundtrip_multichunk(address):
    # Compressible payload, chunk size far below the blob: exercises the
    # RAW_COMPRESSED framing and multi-chunk assembly.
    arr = np.zeros(300_000, dtype=np.float32)
    sobj = serialize(arr)
    cache = BlobCache(8 << 20)
    cache.put("k", FrameBundle.of(sobj))
    server_ledger, client_ledger = TransferLedger(), TransferLedger()
    server = DataServer(
        cache, address, chunk_bytes=100_000, ledger=server_ledger
    )
    client = PeerWireClient(ledger=client_ledger)
    sink = BlobCache(8 << 20)
    try:
        bundle = client.fetch(server.address, "k", sink=sink)
        assert bundle is not None
        np.testing.assert_array_equal(deserialize(bundle), arr)
        assert "k" in sink  # retained for the next consumer
        # Both ends recorded the transfer under the peer-wire link class.
        srow = server_ledger.snapshot()[LINK_PEER]
        crow = client_ledger.snapshot()[LINK_PEER]
        assert srow["logical_bytes"] == crow["logical_bytes"] == sobj.nbytes
        assert srow["wire_bytes"] == crow["wire_bytes"]
        # Zeros compress: the wire carried far fewer bytes than the blob.
        assert srow["wire_bytes"] < sobj.nbytes / 2
        assert client.snapshot()["peer_wire_bytes"] == sobj.nbytes
    finally:
        client.close()
        server.close()


def test_fetch_incompressible_and_miss_reuse(address):
    payload = np.random.default_rng(7).bytes(500_000)
    cache = _served_cache(payload)
    server = DataServer(cache, address, chunk_bytes=150_000)
    client = PeerWireClient()
    try:
        # A miss leaves the stream aligned; the same pooled connection
        # then serves a hit.
        assert client.fetch(server.address, "absent") is None
        bundle = client.fetch(server.address, "k")
        assert bundle is not None and bundle.to_bytes() == payload
    finally:
        client.close()
        server.close()


def test_oversized_fetch_streams_to_disk(tmp_path):
    payload = np.random.default_rng(3).bytes(600_000)
    cache = _served_cache(payload)
    server = DataServer(cache, _inproc_addr(), chunk_bytes=100_000)
    client = PeerWireClient()
    sink = SpillCache(max_bytes=200_000, spill_dir=str(tmp_path))
    try:
        bundle = client.fetch(server.address, "k", sink=sink)
        assert bundle is not None and bundle.to_bytes() == payload
        # Landed straight in the disk tier, never two resident copies.
        assert sink.stats()["spilled_bytes"] >= len(payload)
    finally:
        sink.close()
        client.close()
        server.close()


def test_chunk_bytes_plumbed_into_cluster_mesh():
    from repro.runtime.client import LocalCluster

    with LocalCluster(n_workers=1, transfer={"chunk_bytes": 123_456}) as cluster:
        assert cluster.transfers.chunk_size == 123_456


# ---------------------------------------------------------------------------
# failure modes


class _VanishingCache(BlobCache):
    """Serves ``read_range`` normally ``serve_chunks`` times, then reports
    the blob gone -- a deterministic mid-transfer source loss."""

    def __init__(self, payload: bytes, serve_chunks: int):
        super().__init__(max_bytes=4 * len(payload) + 1024)
        self.put("k", FrameBundle([memoryview(payload)]))
        self.put("good", FrameBundle([memoryview(payload)]))
        self._serves = serve_chunks

    def read_range(self, key, offset, size):
        if key == "k":
            if self._serves <= 0:
                return None
            self._serves -= 1
        return super().read_range(key, offset, size)


def test_abort_mid_transfer_is_clean(address):
    payload = np.random.default_rng(5).bytes(400_000)
    cache = _VanishingCache(payload, serve_chunks=2)
    server = DataServer(cache, address, chunk_bytes=100_000)
    client = PeerWireClient()
    sink = BlobCache(4 << 20)
    try:
        # Source vanishes after 2 of 4 chunks: the server sends an in-band
        # abort, the fetch reports a miss, nothing torn lands in the sink.
        assert client.fetch(server.address, "k", sink=sink) is None
        assert "k" not in sink
        # The abort left the stream aligned: the pooled connection is
        # reused for a clean fetch.
        bundle = client.fetch(server.address, "good", sink=sink)
        assert bundle is not None and bundle.to_bytes() == payload
    finally:
        client.close()
        server.close()


class _StallingCache(BlobCache):
    """First chunk arrives, then serving stalls -- the window in which a
    worker death must wake the blocked fetcher."""

    def __init__(self, payload: bytes, stalled: threading.Event):
        super().__init__(max_bytes=4 * len(payload) + 1024)
        self.put("k", FrameBundle([memoryview(payload)]))
        self._stalled = stalled
        self._calls = 0

    def read_range(self, key, offset, size):
        self._calls += 1
        if self._calls > 1:
            self._stalled.set()
            time.sleep(30)
        return super().read_range(key, offset, size)


def test_server_close_wakes_blocked_fetch(address):
    payload = np.random.default_rng(9).bytes(300_000)
    stalled = threading.Event()
    server = DataServer(
        _StallingCache(payload, stalled), address, chunk_bytes=100_000
    )
    client = PeerWireClient()
    result: list = ["unset"]

    def fetch():
        result[0] = client.fetch(server.address, "k")

    t = threading.Thread(target=fetch, daemon=True)
    t.start()
    assert stalled.wait(10), "fetch never reached the stall point"
    t0 = time.monotonic()
    server.close()  # the dying worker's data server goes away
    t.join(timeout=10)
    assert not t.is_alive(), "blocked fetch never woke"
    # Woke promptly with a miss (store fallback), not a torn bundle and
    # not a 30 s request-timeout wait.
    assert result[0] is None
    assert time.monotonic() - t0 < 5
    client.close()


def test_invalidate_fails_fast_without_dialing():
    payload = b"x" * 1000
    server = DataServer(_served_cache(payload), _inproc_addr())
    client = PeerWireClient()
    try:
        client.invalidate(server.address)  # PEER_GONE push
        t0 = time.monotonic()
        assert client.fetch(server.address, "k") is None
        assert time.monotonic() - t0 < 1
        assert not server._conns  # never even connected
    finally:
        client.close()
        server.close()


def test_concurrent_same_key_fetches_never_tear(address):
    arr = np.arange(200_000, dtype=np.float64)  # 1.6 MB
    sobj = serialize(arr)
    cache = BlobCache(32 << 20)
    cache.put("k", FrameBundle.of(sobj))
    server = DataServer(cache, address, chunk_bytes=64 * 1024)
    client = PeerWireClient(pool_size=2)
    results: list = [None] * 8

    def fetch(i):
        b = client.fetch(server.address, "k")
        results[i] = None if b is None else b.to_bytes()

    try:
        threads = [
            threading.Thread(target=fetch, args=(i,)) for i in range(len(results))
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        expected = sobj.to_bytes()
        # Every fetch that went through the (bounded, reused) pool came
        # back byte-identical -- interleaved requests never cross streams.
        assert all(r == expected for r in results)
    finally:
        client.close()
        server.close()


def test_pool_reuses_a_single_connection():
    payload = b"p" * 200_000
    server = DataServer(_served_cache(payload), _inproc_addr())
    client = PeerWireClient(pool_size=2)
    try:
        for _ in range(5):
            bundle = client.fetch(server.address, "k")
            assert bundle is not None and bundle.to_bytes() == payload
        # Sequential fetches share one pooled connection: the server has
        # accepted exactly one live conn across all five requests.
        assert len(server._conns) == 1
    finally:
        client.close()
        server.close()


# ---------------------------------------------------------------------------
# integration: a real process cluster (slow; mirrors tests/test_comm.py)


def _make_block(i):
    return np.full(400_000, i, dtype=np.float64)  # 3.2 MB


def _sum_blocks(*arrs):
    return float(sum(a.sum() for a in arrs))


def _process_cluster(n_workers=2, **kw):
    from repro.api import ClusterSpec

    kw.setdefault("heartbeat_timeout", 10.0)
    return ClusterSpec(
        n_workers, worker_kind="process", transport="tcp", **kw
    ).build()


@pytest.mark.slow
def test_process_cluster_resolves_deps_over_peer_wire():
    with _process_cluster(2) as cluster:
        cluster.wait_for_workers(timeout=90)
        client = cluster.get_client()
        futs = [client.submit(_make_block, i, pure=False) for i in range(4)]
        [f.result(timeout=120) for f in futs]
        total = client.submit(_sum_blocks, *futs, pure=False)
        assert total.result(timeout=120) == sum(i * 400_000 for i in range(4))
        # The fan-in crossed workers: at least one dependency came over
        # the peer wire, and the ledger's peer-wire row shows it.
        deadline = time.monotonic() + 15
        while time.monotonic() < deadline:
            summary = cluster.transfer_summary()
            stats = cluster.worker_stats()
            hits = sum(s.get("peer_wire_hits", 0) for s in stats.values())
            if hits > 0 and summary.get(LINK_PEER, {}).get("logical_bytes", 0) > 0:
                break
            time.sleep(0.2)
        assert hits > 0, f"no peer-wire fetches: {stats}"
        assert summary[LINK_PEER]["logical_bytes"] > 0
        assert summary[LINK_PEER]["wire_bytes"] > 0


@pytest.mark.slow
def test_killing_serving_worker_falls_back_to_store():
    with _process_cluster(2, heartbeat_timeout=2.0) as cluster:
        cluster.wait_for_workers(timeout=90)
        client = cluster.get_client()
        futs = [client.submit(_make_block, i, pure=False) for i in range(4)]
        [f.result(timeout=120) for f in futs]
        # Kill one worker -- its data server dies with it (any fetch in
        # flight aborts; PEER_GONE invalidates pooled connections).  The
        # fan-in must still complete byte-correctly: published blobs come
        # from the store, unpublished ones through lineage recovery.
        victim = next(iter(cluster.workers))
        cluster.kill_worker(victim)
        total = client.submit(_sum_blocks, *futs, pure=False)
        assert total.result(timeout=120) == sum(i * 400_000 for i in range(4))
