"""Serializer tests: correctness, zero-copy, fast paths, property sweep."""

from __future__ import annotations

import pickle

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional dep (pip install -e .[test])
    # Property tests skip cleanly; the rest of the module still runs.
    from _hypothesis_stub import given, settings, st

from repro.core.serialize import (
    SerializedObject,
    deserialize,
    estimate_size,
    pickle_serializer,
    serialize,
)


def roundtrip(obj):
    return deserialize(serialize(obj).to_bytes())


# -- basic types ---------------------------------------------------------------


@pytest.mark.parametrize(
    "obj",
    [
        42,
        3.14,
        "hello",
        None,
        True,
        [1, 2, 3],
        {"a": 1, "b": [2, 3]},
        (1, "two", 3.0),
        {"nested": {"deep": [1, {"x": (2,)}]}},
        set([1, 2]),
    ],
)
def test_python_roundtrip(obj):
    assert roundtrip(obj) == obj


def test_bytes_roundtrip():
    assert roundtrip(b"abc\x00def") == b"abc\x00def"
    assert roundtrip(bytearray(b"xy")) == b"xy"


@pytest.mark.parametrize(
    "dtype",
    [np.float64, np.float32, np.float16, np.int64, np.int32, np.int8,
     np.uint8, np.bool_, np.complex64],
)
def test_ndarray_dtypes(dtype):
    a = np.arange(64).astype(dtype)
    b = roundtrip(a)
    assert b.dtype == a.dtype
    np.testing.assert_array_equal(a, b)


def test_ndarray_shapes():
    for shape in [(), (1,), (3, 4), (2, 3, 4, 5), (0,), (5, 0, 2)]:
        a = np.random.default_rng(0).normal(size=shape).astype(np.float32)
        b = roundtrip(a)
        assert b.shape == a.shape
        np.testing.assert_array_equal(a, b)


def test_bfloat16_jax_array():
    import jax.numpy as jnp

    a = jnp.arange(300, dtype=jnp.bfloat16) / 7
    b = roundtrip(a)
    np.testing.assert_array_equal(np.asarray(a).view(np.uint16),
                                  np.asarray(b).view(np.uint16))


def test_noncontiguous_array():
    a = np.arange(64.0).reshape(8, 8)[::2, ::2]
    assert not a.flags.c_contiguous
    np.testing.assert_array_equal(roundtrip(a), a)


def test_fortran_order_array():
    a = np.asfortranarray(np.arange(900.0).reshape(30, 30))
    np.testing.assert_array_equal(roundtrip(a), a)


# -- pytrees --------------------------------------------------------------------


def test_pytree_of_arrays():
    tree = {
        "params": {"w": np.ones((128, 16), np.float32), "b": np.zeros(16)},
        "step": 3,
        "name": "model",
    }
    out = roundtrip(tree)
    np.testing.assert_array_equal(out["params"]["w"], tree["params"]["w"])
    np.testing.assert_array_equal(out["params"]["b"], tree["params"]["b"])
    assert out["step"] == 3 and out["name"] == "model"


def test_jax_pytree_roundtrip():
    import jax
    import jax.numpy as jnp

    tree = {"a": jnp.ones((17, 3)), "b": [jnp.zeros(5, jnp.int32), 7]}
    out = roundtrip(tree)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    np.testing.assert_array_equal(np.asarray(tree["a"]), out["a"])


# -- proxies serialize as references, never as bytes -----------------------------


def test_proxy_stays_proxy(store):
    from repro.core import is_proxy, is_resolved

    big = np.zeros(500_000)
    p = store.proxy(big)
    blob = serialize(p).to_bytes()
    assert len(blob) < 4096  # factory only
    q = deserialize(blob)
    assert is_proxy(q) and not is_resolved(q)
    np.testing.assert_array_equal(np.asarray(q), big)


def test_container_of_proxies(store):
    from repro.core import is_proxy

    arr = np.ones(100_000)
    msg = {"data": store.proxy(arr), "tag": 1}
    blob = serialize(msg).to_bytes()
    assert len(blob) < 8192
    out = deserialize(blob)
    assert is_proxy(out["data"])


# -- zero-copy claims -------------------------------------------------------------


def test_serialize_is_zero_copy_for_big_arrays():
    a = np.arange(1 << 16, dtype=np.float64)
    s = serialize(a)
    # the frame must be a view over a's memory, not a copy
    assert len(s.buffers) == 1
    assert np.shares_memory(np.frombuffer(s.buffers[0], np.float64), a)


def test_deserialize_returns_views():
    a = np.arange(1 << 14, dtype=np.float32)
    blob = serialize(a).to_bytes()
    out = deserialize(blob)
    assert not out.flags.writeable  # view over the immutable blob
    np.testing.assert_array_equal(out, a)


def test_frames_vs_to_bytes_consistency():
    tree = {"w": np.ones(4096, np.float32), "k": "v"}
    s = serialize(tree)
    joined = b"".join(bytes(f) for f in s.frames())
    assert joined == s.to_bytes()
    assert s.nbytes == len(joined)


def test_small_arrays_inline_in_header():
    s = serialize(np.arange(4, dtype=np.int8))  # < 512B -> header-inline
    assert len(s.buffers) == 0


# -- sizes / fallback ---------------------------------------------------------------


def test_magic_check():
    with pytest.raises(ValueError):
        deserialize(b"NOPE" + b"\x00" * 16)


def test_custom_object_falls_back_to_pickle():
    class Thing:
        def __init__(self, x):
            self.x = x

        def __eq__(self, other):
            return self.x == other.x

    # class defined in a test function is picklable? no -- use dict instead
    obj = {"fn": abs, "data": b"\x01" * 2000}
    out = roundtrip(obj)
    assert out["fn"] is abs and out["data"] == obj["data"]


def test_estimate_size():
    assert estimate_size(np.zeros(1000, np.float64)) == 8000
    assert estimate_size(b"x" * 100) == 100
    assert estimate_size("y" * 50) == 50
    assert estimate_size([np.zeros(100, np.uint8)]) >= 100
    d = {"k": np.zeros(256, np.uint8)}
    assert estimate_size(d) >= 256
    assert estimate_size(7) > 0


def test_pickle_serializer_baseline():
    a = np.arange(1000.0)
    s = pickle_serializer(a)
    assert isinstance(s, SerializedObject)
    out = pickle.loads(bytes(s.buffers[0]))
    np.testing.assert_array_equal(out, a)


def test_fastpath_smaller_than_pickle_for_arrays():
    """The 2-3x speed claim comes with near-1x size: header + raw bytes."""
    a = np.random.default_rng(1).normal(size=(512, 256)).astype(np.float32)
    fast = serialize(a).nbytes
    assert fast <= len(pickle.dumps(a, protocol=5)) + 1024
    assert fast >= a.nbytes  # sanity: can't be smaller than the data


# -- property-based sweep ------------------------------------------------------------


array_dtypes = st.sampled_from(
    [np.float32, np.float64, np.int32, np.int64, np.uint8, np.float16]
)
small_shapes = st.lists(st.integers(0, 7), min_size=0, max_size=3).map(tuple)


@settings(max_examples=60, deadline=None)
@given(dtype=array_dtypes, shape=small_shapes, seed=st.integers(0, 2**31 - 1))
def test_property_array_roundtrip(dtype, shape, seed):
    rng = np.random.default_rng(seed)
    a = (rng.normal(size=shape) * 100).astype(dtype)
    b = roundtrip(a)
    assert b.shape == a.shape and b.dtype == a.dtype
    np.testing.assert_array_equal(a, b)


json_like = st.recursive(
    st.none() | st.booleans() | st.integers(-10, 10) | st.text(max_size=8),
    lambda children: st.lists(children, max_size=4)
    | st.dictionaries(st.text(max_size=4), children, max_size=4),
    max_leaves=12,
)


@settings(max_examples=60, deadline=None)
@given(obj=json_like)
def test_property_pytree_roundtrip(obj):
    assert roundtrip(obj) == obj


@settings(max_examples=30, deadline=None)
@given(
    data=st.binary(max_size=4096),
)
def test_property_bytes_roundtrip(data):
    assert roundtrip(data) == data


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(0, 200),
    dtype=array_dtypes,
)
def test_property_mixed_tree(n, dtype):
    tree = {"a": np.arange(n, dtype=dtype), "meta": {"n": n}, "l": [1, "x"]}
    out = roundtrip(tree)
    np.testing.assert_array_equal(out["a"], tree["a"])
    assert out["meta"]["n"] == n and out["l"] == [1, "x"]
