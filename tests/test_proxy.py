"""Unit tests for the transparent object proxy (paper §2/§3)."""

from __future__ import annotations

import pickle

import numpy as np
import pytest

from repro.core import (
    Proxy,
    ProxyResolveError,
    SimpleFactory,
    LambdaFactory,
    TargetMetadata,
    extract,
    get_factory,
    get_metadata,
    is_proxy,
    is_resolved,
    proxy_token,
    resolve,
)


def make(obj, **kw):
    return Proxy(SimpleFactory(obj))


# -- transparency: the proxy forwards everything ------------------------------


def test_arithmetic_forwarding():
    p = make(10)
    assert p + 5 == 15
    assert 5 + p == 15
    assert p * 2 == 20
    assert 2**p == 1024
    assert p - 3 == 7
    assert 21 // p == 2
    assert divmod(p, 3) == (3, 1)
    assert -p == -10
    assert abs(make(-3)) == 3


def test_proxy_plus_proxy():
    assert make(2) + make(3) == 5


def test_comparison_forwarding():
    p = make(10)
    assert p == 10 and p != 11
    assert p < 11 and p <= 10 and p > 9 and p >= 10


def test_container_forwarding():
    p = make([1, 2, 3])
    assert len(p) == 3
    assert p[0] == 1
    assert list(p) == [1, 2, 3]
    assert 2 in p
    assert list(reversed(p)) == [3, 2, 1]
    p[0] = 99
    assert p[0] == 99
    del p[0]
    assert len(p) == 2


def test_dict_forwarding():
    p = make({"a": 1})
    assert p["a"] == 1
    assert "a" in p
    assert p.keys() == {"a": 1}.keys()


def test_string_behavior():
    p = make("hello")
    assert str(p) == "hello"
    assert p.upper() == "HELLO"
    assert format(p, ">7") == "  hello"
    assert p + " world" == "hello world"


def test_callable_forwarding():
    p = make(lambda x: x * 2)
    assert p(21) == 42


def test_attribute_get_set():
    class Obj:
        x = 1

    o = Obj()
    p = make(o)
    assert p.x == 1
    p.y = 5
    assert o.y == 5
    del p.y
    assert not hasattr(o, "y")


def test_bool_bytes():
    assert bool(make([1]))
    assert not bool(make([]))
    assert bytes(make(b"ab")) == b"ab"


def test_numpy_transparency():
    a = np.arange(12.0).reshape(3, 4)
    p = make(a)
    np.testing.assert_array_equal(np.asarray(p), a)
    np.testing.assert_array_equal(p + 1, a + 1)
    np.testing.assert_array_equal(p @ a.T, a @ a.T)
    assert (p.sum() == a.sum()).all()


def test_jax_array_protocol():
    import jax
    import jax.numpy as jnp

    a = np.arange(8.0, dtype=np.float32)
    p = make(a)

    @jax.jit
    def f(x):
        return (x * 2).sum()

    # explicit conversion resolves the proxy at the XLA boundary
    assert float(f(jnp.array(p))) == float(a.sum() * 2)
    assert float(f(np.asarray(p))) == float(a.sum() * 2)


# -- laziness + metadata caching (paper §3 "Compatibility") --------------------


def test_lazy_until_used():
    calls = []

    def factory():
        calls.append(1)
        return 42

    p = Proxy(LambdaFactory(factory))
    assert not is_resolved(p)
    assert calls == []
    assert p + 0 == 42
    assert is_resolved(p)
    assert calls == [1]
    assert p + 0 == 42
    assert calls == [1]  # resolved once, cached


def test_metadata_never_resolves():
    """Scheduler-style introspection must not fire the factory."""
    md = TargetMetadata.from_target(np.zeros((3, 4), np.float32))

    def boom():
        raise AssertionError("resolved!")

    p = Proxy(LambdaFactory(boom, md=md))
    assert p.__class__ is np.ndarray
    assert isinstance(p, np.ndarray)  # isinstance consults __class__
    assert p.__module__ == "numpy"
    assert p.shape == (3, 4)
    assert p.dtype == np.float32
    assert p.nbytes == 48
    assert len(p) == 3
    assert not is_resolved(p)


def test_hash_cached_for_hashables():
    md = TargetMetadata.from_target("hello")

    def boom():
        raise AssertionError("resolved!")

    p = Proxy(LambdaFactory(boom, md=md))
    assert hash(p) == hash("hello")
    assert not is_resolved(p)


def test_hash_unhashable_raises_without_resolving():
    md = TargetMetadata.from_target([1, 2])
    p = Proxy(LambdaFactory(lambda: [1, 2], md=md))
    with pytest.raises(TypeError):
        hash(p)
    assert not is_resolved(p)


def test_repr_unresolved_does_not_resolve():
    p = Proxy(SimpleFactory([1, 2]))
    r = repr(p)
    assert "unresolved" in r
    assert not is_resolved(p)
    _ = p[0]
    assert "unresolved" not in repr(p)


def test_class_cached_for_jax_arrays():
    import jax
    import jax.numpy as jnp

    x = jnp.ones((2,))
    p = make(x)
    # private jaxlib ArrayImpl is advertised as the public ABC
    assert p.__class__ is jax.Array
    assert isinstance(p, jax.Array)


# -- serialization of the proxy itself ----------------------------------------


def test_pickle_roundtrip_is_cheap_and_lazy(store):
    big = np.zeros(1_000_000)
    p = store.proxy(big)  # store-backed: pickles as (config, key) only
    blob = pickle.dumps(p)
    assert len(blob) < len(pickle.dumps(big)) // 100  # factory only... tiny
    q = pickle.loads(blob)
    assert is_proxy(q)
    assert not is_resolved(q)
    np.testing.assert_array_equal(np.asarray(q), big)


def test_pickle_preserves_metadata_laziness(store):
    p = store.proxy(np.zeros((5,)))
    q = pickle.loads(pickle.dumps(p))
    # metadata travels with the factory; shape introspection stays lazy
    assert q.shape == (5,)
    assert not is_resolved(q)


# -- helpers ------------------------------------------------------------------


def test_is_proxy_and_extract():
    p = make(7)
    assert is_proxy(p)
    assert not is_proxy(7)
    assert extract(p) == 7
    assert extract(7) == 7


def test_resolve_eager():
    p = make("x")
    assert resolve(p) == "x"
    assert is_resolved(p)


def test_get_factory_and_metadata():
    f = SimpleFactory(3)
    p = Proxy(f)
    assert get_factory(p) is f
    assert get_metadata(p).cls is int


def test_proxy_token_from_metadata():
    md = TargetMetadata.from_target(1, token="tok-123")
    p = Proxy(LambdaFactory(lambda: 1, md=md))
    assert proxy_token(p) == "tok-123"
    assert proxy_token(42) is None


def test_store_factory_missing_object_raises(store):
    p = store.proxy(np.arange(4))
    key = get_factory(p).key
    store.evict(key)
    # also purge the store-side LRU so resolution truly misses
    store._cache.pop(key.object_id)
    with pytest.raises(ProxyResolveError):
        resolve(p)


def test_isinstance_type_check_no_resolution(store):
    """The paper's motivating bug: Dask type-dispatch resolved proxies."""
    p = store.proxy(np.arange(4))
    assert isinstance(p, np.ndarray)
    assert not is_resolved(p)
