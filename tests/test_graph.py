"""Graph-native submission: TaskGraph builder, SUBMIT_GRAPH batching,
pipelined RUN_BATCH dispatch, and confirm-based work stealing."""

from __future__ import annotations

import threading
import time

import pytest

from repro.api import ClusterSpec, Session, TaskGraph
from repro.runtime import messages as M
from repro.runtime.client import LocalCluster


def double(x):
    return x * 2


def add(a, b):
    return a + b


def total(xs):
    return sum(xs)


def wait_until(cond, timeout=10.0, interval=0.02):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(interval)
    return cond()


@pytest.fixture()
def cluster():
    with LocalCluster(n_workers=2) as c:
        yield c


# -- builder -------------------------------------------------------------------


def test_graph_builder_dedups_pure_nodes():
    g = TaskGraph()
    a = g.add(double, 21)
    b = g.add(double, 21)  # identical pure call -> same node
    c = g.add(double, 22)
    assert a.key == b.key
    assert a.key != c.key
    assert len(g) == 2


def test_graph_builder_topo_order_and_outputs():
    g = TaskGraph()
    a = g.add(double, 1)
    b = g.add(double, 2)
    s = g.add(add, a, b)
    keys = [k for k, _ in g.items()]
    assert keys.index(s.key) > keys.index(a.key)
    assert keys.index(s.key) > keys.index(b.key)
    assert [n.key for n in g.outputs()] == [s.key]


def test_graph_rejects_foreign_nodes():
    g1, g2 = TaskGraph(), TaskGraph()
    a = g1.add(double, 1)
    with pytest.raises(ValueError, match="different TaskGraph"):
        g2.add(double, a)


def test_graph_impure_nodes_never_dedup():
    g = TaskGraph()
    a = g.add(double, 21, pure=False)
    b = g.add(double, 21, pure=False)
    assert a.key != b.key
    assert len(g) == 2


# -- cluster execution ---------------------------------------------------------


def test_fanout_fanin_result(cluster):
    with cluster.get_client() as client:
        g = TaskGraph()
        nodes = [g.add(double, i) for i in range(32)]
        g.add(total, nodes)
        [fut] = client.submit_graph(g)
        assert fut.result(timeout=30) == sum(i * 2 for i in range(32))


def test_diamond_dependencies(cluster):
    with cluster.get_client() as client:
        g = TaskGraph()
        a = g.add(double, 10)
        left = g.add(double, a)
        right = g.add(add, a, 1)
        sink = g.add(add, left, right)
        [fut] = client.submit_graph(g, nodes=[sink])
        assert fut.result(timeout=30) == (40 + 21)


def test_graph_node_depends_on_submitted_future(cluster):
    """A live future is a legal cross-graph dependency."""
    with cluster.get_client() as client:
        upstream = client.submit(double, 5)
        g = TaskGraph()
        sink = g.add(add, upstream, 1)
        [fut] = client.submit_graph(g, nodes=[sink])
        assert fut.result(timeout=30) == 11


def test_interior_nodes_send_no_finished(cluster):
    """Only requested outputs generate client traffic."""
    with cluster.get_client() as client:
        g = TaskGraph()
        nodes = [g.add(double, 100 + i) for i in range(16)]
        g.add(total, nodes)
        m0 = cluster.scheduler.bytes_through()["out_msgs"]
        [fut] = client.submit_graph(g)
        fut.result(timeout=30)
        out_msgs = cluster.scheduler.bytes_through()["out_msgs"] - m0
        # dispatch batches + exactly one FINISHED; nowhere near one per task
        assert out_msgs < 10


def test_graph_error_cascades_to_sink(cluster):
    def boom(x):
        raise ValueError("graph boom")

    with cluster.get_client() as client:
        g = TaskGraph()
        bad = g.add(boom, 1, retries=0)
        sink = g.add(add, bad, 1)
        [fut] = client.submit_graph(g, nodes=[sink])
        with pytest.raises(RuntimeError, match="graph boom|dependency"):
            fut.result(timeout=30)


# -- edge cases required by the issue ------------------------------------------


def test_duplicate_keys_across_two_graphs(cluster):
    """The same pure node submitted via two graphs runs once; both futures
    resolve from the shared computation."""
    calls = []

    def tracked(x):
        calls.append(x)
        return x + 1

    with cluster.get_client() as client:
        g1 = TaskGraph()
        n1 = g1.add(tracked, 5)
        [f1] = client.submit_graph(g1, nodes=[n1])
        assert f1.result(timeout=30) == 6

        g2 = TaskGraph()
        n2 = g2.add(tracked, 5)  # same fn+args -> same key
        assert n2.key == n1.key
        [f2] = client.submit_graph(g2, nodes=[n2])
        assert f2.result(timeout=30) == 6
        assert len(calls) == 1  # pure cache hit across graphs


def test_graph_dep_on_released_key_fails_fast(cluster):
    """A graph node depending on an already-released key must fail fast,
    not hang waiting for a completion that can never come."""
    with cluster.get_client() as client:
        upstream = client.submit(double, 77, pure=False)
        upstream.result(timeout=30)
        client.release([upstream])
        assert wait_until(
            lambda: upstream.key not in cluster.scheduler.tasks, timeout=10
        )
        g = TaskGraph()
        sink = g.add(add, upstream, 1)
        [fut] = client.submit_graph(g, nodes=[sink])
        with pytest.raises(RuntimeError, match="unknown or released"):
            fut.result(timeout=30)


def test_work_stealing_never_double_runs():
    """An idle worker steals from a loaded worker's unstarted backlog, and
    every task still executes exactly once."""
    counts: dict[int, int] = {}
    lock = threading.Lock()

    def slowish(i):
        with lock:
            counts[i] = counts.get(i, 0) + 1
        time.sleep(0.04)
        return i

    # speculation off (speculation_min) so only stealing can move work
    with LocalCluster(n_workers=1, speculation_min=120.0) as cluster:
        with cluster.get_client() as client:
            futs = client.map(slowish, list(range(30)), pure=False)
            time.sleep(0.1)  # worker-0 starts chewing its whole batch
            thief = cluster.add_worker()
            assert sorted(client.gather(futs)) == list(range(30))
            dupes = {k: v for k, v in counts.items() if v != 1}
            assert not dupes, f"stolen tasks ran twice: {dupes}"
            # the steal actually happened: the thief did real work
            sched_thief = cluster.scheduler.workers.get(thief)
            assert sched_thief is not None and sched_thief.total_done > 0


def test_steal_ack_for_started_tasks_keeps_them(cluster):
    """A STEAL naming a task the worker already started (or finished) is
    acked as not-taken and the task is not re-queued."""
    with cluster.get_client() as client:
        fut = client.submit(double, 333, pure=False)
        assert fut.result(timeout=30) == 666
        sched = cluster.scheduler
        worker_id = next(iter(sched.workers))
        ws = sched.workers[worker_id]
        worker = cluster.workers[worker_id]
        m0 = sched.inbox.counter.snapshot()["recv_msgs"]
        worker.mailbox.put_msg(M.msg(M.STEAL, keys=[fut.key]))
        assert wait_until(
            lambda: sched.inbox.counter.snapshot()["recv_msgs"] > m0, timeout=10
        )
        time.sleep(0.2)  # let the scheduler process the ack
        assert fut.key not in sched.ready
        assert fut.key not in ws.running


# -- Session facade ------------------------------------------------------------


def test_session_compute_cluster():
    with Session(backend="cluster", cluster=ClusterSpec(n_workers=2)) as s:
        g = s.graph()
        nodes = [g.add(double, i) for i in range(8)]
        sink = g.add(total, nodes)
        assert s.compute(g, nodes=sink) == sum(i * 2 for i in range(8))


def test_session_compute_inprocess_and_executor():
    for backend in ("in-process", "executor"):
        with Session(backend=backend) as s:
            g = TaskGraph()
            a = g.add(double, 3)
            b = g.add(add, a, 4)
            assert s.compute(g) == [10]
            assert s.compute(g, nodes=b) == 10


def test_map_kwarg_named_key_reaches_function(cluster):
    """A user fn kwarg named `key` (or `pure`) must not be swallowed by
    the graph builder's reserved task parameters."""

    def scale(x, key=1.0):
        return x * key

    with cluster.get_client() as client:
        assert client.gather(client.map(scale, [1, 2, 3], key=2.0)) == [2.0, 4.0, 6.0]


def test_noncluster_graph_resolves_future_args():
    """Graph code is portable: local Futures passed as node args resolve
    on the in-process and executor backends too."""
    for backend in ("in-process", "executor"):
        with Session(backend=backend) as s:
            up = s.submit(double, 5)
            g = TaskGraph()
            sink = g.add(add, up, 1)
            assert s.compute(g, nodes=sink) == 11


def test_noncluster_graph_rejects_foreign_nodes_before_running():
    ran = []

    def tracked(x):
        ran.append(x)
        return x

    g1, g2 = TaskGraph(), TaskGraph()
    g1.add(tracked, 1)
    other = g2.add(double, 2)
    with Session() as s:
        with pytest.raises(ValueError, match="not part of this graph"):
            s.submit_graph(g1, nodes=[other])
    assert ran == []  # nothing executed before validation


def test_session_map_batches_into_one_submission():
    with Session(backend="cluster", cluster=ClusterSpec(n_workers=2)) as s:
        sched = s.cluster.scheduler
        m0 = sched.inbox.counter.snapshot()["recv_msgs"]
        futs = s.map(double, list(range(20)))
        assert s.gather(futs) == [i * 2 for i in range(20)]
        # 1 SUBMIT_GRAPH + coalesced completion reports + heartbeats;
        # far fewer inbound messages than one SUBMIT per task
        in_msgs = sched.inbox.counter.snapshot()["recv_msgs"] - m0
        assert in_msgs < 20
