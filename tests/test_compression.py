"""Gradient/state compression tests (distributed/compression.py)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional dep (pip install -e .[test])
    # Property tests skip cleanly; the rest of the module still runs.
    from _hypothesis_stub import given, settings, st

from repro.distributed.compression import (
    CompressedDeltaCodec,
    compress_with_feedback,
    dequantize_int8,
    dequantize_tree,
    init_error_feedback,
    payload_nbytes,
    quantize_int8,
    quantize_tree,
)

rng = np.random.default_rng(0)


def test_int8_roundtrip_error_bound():
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    q, s = quantize_int8(x, block=256)
    back = dequantize_int8(q, s, x.shape)
    # error bounded by half a quantization step per block
    step = np.repeat(np.asarray(s), 256)[:1000]
    assert np.all(np.abs(np.asarray(back - x)) <= step * 0.5 + 1e-7)


def test_quantize_zero_and_constant():
    z = jnp.zeros(100)
    q, s = quantize_int8(z)
    np.testing.assert_array_equal(np.asarray(dequantize_int8(q, s, z.shape)), 0)
    c = jnp.full(100, 3.25)
    q, s = quantize_int8(c)
    np.testing.assert_allclose(np.asarray(dequantize_int8(q, s, c.shape)), 3.25,
                               rtol=1e-2)


def test_tree_roundtrip():
    tree = {"a": jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32)),
            "b": [jnp.asarray(rng.normal(size=(7,)).astype(np.float32))]}
    qt = quantize_tree(tree)
    back = dequantize_tree(qt)
    for o, r in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert r.shape == o.shape
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-2)


def test_error_feedback_unbiased_over_steps():
    """Mean of dequantized grads converges to the true mean (EF property)."""
    true_grad = jnp.asarray(rng.normal(size=(512,)).astype(np.float32)) * 1e-3
    residual = init_error_feedback({"g": true_grad})
    acc = np.zeros(512)
    steps = 50
    for _ in range(steps):
        qt, residual = compress_with_feedback({"g": true_grad}, residual)
        acc += np.asarray(dequantize_int8(*qt["g"][:2], true_grad.shape))
    mean_err = np.abs(acc / steps - np.asarray(true_grad)).max()
    naive_q, naive_s = quantize_int8(true_grad)
    naive_err = np.abs(
        np.asarray(dequantize_int8(naive_q, naive_s, true_grad.shape))
        - np.asarray(true_grad)
    ).max()
    assert mean_err < naive_err / 3  # feedback beats memoryless quantization


def test_compression_ratio():
    tree = {"w": jnp.asarray(rng.normal(size=(256, 256)).astype(np.float32))}
    qt = quantize_tree(tree)
    raw = 256 * 256 * 4
    assert payload_nbytes(qt) < raw / 3  # ~4x minus scale overhead


def test_delta_codec_roundtrip_and_size():
    base = {"w": rng.normal(size=(128, 128)).astype(np.float32)}
    codec = CompressedDeltaCodec(base)
    stepped = {"w": base["w"] + rng.normal(size=(128, 128)).astype(np.float32) * 1e-3}
    payload = codec.encode(stepped)
    out = codec.decode(payload)
    # delta quantization error is relative to the *delta* scale -> tiny
    # (half-step = max|delta|/254 per block ~ 2e-5 here)
    np.testing.assert_allclose(out["w"], stepped["w"], atol=5e-5)
    assert payload_nbytes(payload) < 128 * 128 * 4 / 3


def test_delta_codec_rebase():
    base = {"w": np.zeros(64, np.float32)}
    codec = CompressedDeltaCodec(base)
    s1 = {"w": np.full(64, 10.0, np.float32)}
    codec.rebase(s1)
    payload = codec.encode({"w": s1["w"] + 0.001})
    out = codec.decode(payload)
    np.testing.assert_allclose(out["w"], s1["w"] + 0.001, atol=1e-6)


def test_delta_codec_through_store(store):
    """Composition with the paper's plane: deltas proxied through the Store."""
    from repro.core import is_proxy

    base = {"w": rng.normal(size=(256, 256)).astype(np.float32)}
    codec = CompressedDeltaCodec(base)
    new_state = {"w": base["w"] * 1.001}
    p = store.proxy(codec.encode(new_state))
    assert is_proxy(p)
    out = codec.decode({"w": tuple(p["w"])})
    np.testing.assert_allclose(out["w"], new_state["w"], rtol=1e-3, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 2048), seed=st.integers(0, 2**31 - 1),
       scale=st.floats(1e-6, 1e3))
def test_property_quantize_bounded(n, seed, scale):
    r = np.random.default_rng(seed)
    x = jnp.asarray((r.normal(size=(n,)) * scale).astype(np.float32))
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s, x.shape)
    blk = np.repeat(np.asarray(s), 256)[:n]
    assert np.all(np.abs(np.asarray(back - x)) <= blk * 0.51 + 1e-9)
