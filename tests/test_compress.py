"""Adaptive per-link compression: codecs, probe, envelope, ledger, specs.

Covers the compression tentpole end to end at the unit level:

* every registered codec round-trips (including empty / 1-byte /
  misaligned frames -- the shapes that break block codecs),
* the decision probe (size gate, entropy bail-out, link-class hard-wiring:
  shm and inproc must never compress),
* the self-describing envelope in both its contiguous (tcp / file) and
  frame-preserved (store) forms,
* :class:`TransferLedger` accounting sums and cluster-wide ``merge``,
* :class:`TransferSpec` validation + dict round-trip,
* the byte paths that consume all of the above: ``SpillCache`` compressed
  demote/restore and ``ResultStore`` publish/fetch over a cross-process
  connector,
* the ``dequantize_int8`` dtype regression (bf16/f16 states must decode
  back to their own dtype, not float32).
"""

from __future__ import annotations

import uuid

import numpy as np
import pytest

from repro.api import ClusterSpec, SpecValidationError, StoreConfig, TransferSpec
from repro.core.compress import (
    LINK_INPROC,
    LINK_PROCESS,
    LINK_SHM,
    LINK_TCP,
    NEVER_COMPRESS_LINKS,
    TransferLedger,
    TransferPolicy,
    available_codecs,
    compress_frames,
    decompress_frames,
    is_compressed,
    resolve_codec,
)
from repro.runtime.transfer import ResultStore, SpillCache

# ---------------------------------------------------------------------------
# codecs


def _payloads():
    rng = np.random.default_rng(7)
    ramp = (np.arange(100_000, dtype=np.float32) * 0.001).tobytes()
    return {
        "empty": b"",
        "one": b"x",
        "misaligned": bytes(rng.integers(0, 4, 4097, dtype=np.uint8)),
        "zeros": bytes(64 * 1024),
        "zeros+tail": bytes(2 * 4096) + b"tail-bytes!",
        "random": rng.bytes(50_000),
        "f32-ramp": ramp,
    }


@pytest.mark.parametrize("name", sorted(set(available_codecs())))
def test_codec_roundtrip_every_shape(name):
    codec = resolve_codec(name)
    for label, payload in _payloads().items():
        stored = codec.encode(memoryview(payload))
        back = codec.decode(memoryview(stored), len(payload))
        assert bytes(back) == payload, f"{name} broke on {label}"


def test_lz4_always_nameable():
    # With the optional package absent the registry aliases lz4 -> zlib;
    # either way the name resolves and the codec round-trips.
    assert "lz4" in available_codecs()
    codec = resolve_codec("lz4")
    data = bytes(range(256)) * 64
    assert bytes(codec.decode(memoryview(codec.encode(memoryview(data))), len(data))) == data


def test_cascade_suppresses_zero_blocks():
    codec = resolve_codec("cascade")
    data = bytes(1 << 20)  # 256 all-zero 4 KiB blocks
    stored = codec.encode(memoryview(data))
    assert len(stored) < 1024
    assert bytes(codec.decode(memoryview(stored), len(data))) == data


def test_unknown_codec_rejected():
    with pytest.raises(ValueError, match="unknown codec"):
        resolve_codec("snappy")
    with pytest.raises(ValueError):
        TransferPolicy("snappy")


# ---------------------------------------------------------------------------
# decision probe


def test_probe_size_gate():
    policy = TransferPolicy("auto", min_frame_bytes=64 * 1024)
    small = memoryview(bytes(64 * 1024 - 1))
    big = memoryview(bytes(64 * 1024))
    assert policy.select(small, LINK_TCP) is None
    assert policy.select(big, LINK_TCP) is not None


def test_probe_entropy_bailout_on_random():
    policy = TransferPolicy("auto", min_frame_bytes=1024)
    noise = memoryview(np.random.default_rng(0).bytes(1 << 20))
    assert policy.select(noise, LINK_TCP) is None


def test_never_compress_links_hard_wired():
    # Even a forced codec and a trivially compressible frame must ship raw
    # on the zero-copy links: compression there would *add* a copy.
    policy = TransferPolicy("cascade", min_frame_bytes=0)
    zeros = memoryview(bytes(1 << 20))
    for link in sorted(NEVER_COMPRESS_LINKS):
        assert policy.select(zeros, link) is None
        assert compress_frames([zeros], policy=policy, link_class=link) is None
    assert policy.select(zeros, LINK_TCP) is not None
    assert policy.select(zeros, LINK_PROCESS) is not None


def test_policy_off_and_forced():
    zeros = memoryview(bytes(1 << 20))
    assert TransferPolicy("off").select(zeros, LINK_TCP) is None
    forced = TransferPolicy("zlib", min_frame_bytes=1024)
    assert forced.select(zeros, LINK_TCP).name == "zlib"


def test_policy_config_roundtrip():
    policy = TransferPolicy(
        "cascade", min_frame_bytes=2048, probe_ratio=0.5, spill_compression="zlib"
    )
    again = TransferPolicy.from_config(policy.to_dict())
    assert again.to_dict() == policy.to_dict()
    assert TransferPolicy.from_config(None).compression == "auto"
    assert TransferPolicy.from_config("off").compression == "off"


# ---------------------------------------------------------------------------
# envelope


def _mixed_frames():
    rng = np.random.default_rng(3)
    return [
        bytes(256 * 1024),  # compressible
        rng.bytes(128 * 1024),  # incompressible: rides raw in the envelope
        b"tiny",  # under the size gate
    ]


def test_envelope_roundtrip_contiguous_and_frame_list():
    frames = _mixed_frames()
    policy = TransferPolicy("auto", min_frame_bytes=1024)
    packed = compress_frames(frames, policy=policy, link_class=LINK_TCP)
    assert packed is not None
    envelope, stats = packed
    assert is_compressed(envelope)
    logical = sum(len(f) for f in frames)
    assert stats["logical_bytes"] == logical
    assert stats["wire_bytes"] < logical  # the zero frame collapsed
    assert 0 < stats["compressed_bytes"] <= logical
    assert stats["wire_bytes"] == sum(memoryview(f).nbytes for f in envelope)

    # Contiguous form: what tcp / a file store hands back.
    joined = b"".join(bytes(f) for f in envelope)
    restored = decompress_frames(joined)
    assert [bytes(f) for f in restored] == frames

    # Frame-preserved form: what a frame-retaining store hands back.
    restored = decompress_frames(envelope)
    assert [bytes(f) for f in restored] == frames


def test_envelope_never_double_wraps():
    policy = TransferPolicy("auto", min_frame_bytes=1024)
    packed = compress_frames([bytes(256 * 1024)], policy=policy, link_class=LINK_TCP)
    assert packed is not None
    assert compress_frames(packed[0], policy=policy, link_class=LINK_TCP) is None


def test_all_incompressible_declines():
    noise = np.random.default_rng(1).bytes(256 * 1024)
    policy = TransferPolicy("auto", min_frame_bytes=1024)
    assert compress_frames([noise], policy=policy, link_class=LINK_TCP) is None
    assert not is_compressed([noise])


def test_full_frame_bailout_ships_raw():
    # First+last windows are zeros (the probe approves) but the body is
    # noise: the full encode does not pay, so the frame must ride raw --
    # codec id 0 -- rather than grow the wire.
    rng = np.random.default_rng(9)
    frame = bytes(8192) + rng.bytes(1 << 20) + bytes(8192)
    policy = TransferPolicy("zlib", min_frame_bytes=1024)
    packed = compress_frames([frame], policy=policy, link_class=LINK_TCP)
    if packed is not None:  # zlib found a sliver; delivery must still be exact
        assert bytes(b"".join(bytes(f) for f in decompress_frames(packed[0]))) == frame


# ---------------------------------------------------------------------------
# ledger


def test_ledger_sums_and_derived_fields():
    ledger = TransferLedger()
    ledger.record(LINK_TCP, logical_bytes=100, wire_bytes=25, compressed_bytes=100, compress_ns=10)
    ledger.record(LINK_TCP, logical_bytes=100, wire_bytes=75, decompress_ns=30)
    ledger.record(LINK_SHM, logical_bytes=50, wire_bytes=50)
    snap = ledger.snapshot()
    tcp = snap[LINK_TCP]
    assert tcp["transfers"] == 2
    assert tcp["logical_bytes"] == 200
    assert tcp["wire_bytes"] == 100
    assert tcp["compressed_bytes"] == 100
    assert tcp["ratio"] == pytest.approx(2.0)
    assert tcp["codec_mib_s"] > 0
    shm = snap[LINK_SHM]
    assert shm["ratio"] == pytest.approx(1.0)
    assert shm["compressed_bytes"] == 0
    assert shm["codec_mib_s"] == 0.0


def test_ledger_merge_aggregates_per_link():
    a, b = TransferLedger(), TransferLedger()
    a.record(LINK_TCP, logical_bytes=10, wire_bytes=5)
    b.record(LINK_TCP, logical_bytes=30, wire_bytes=15)
    b.record(LINK_INPROC, logical_bytes=7, wire_bytes=7)
    merged = TransferLedger.merge([a.snapshot(), b.snapshot(), {}])
    assert merged[LINK_TCP]["transfers"] == 2
    assert merged[LINK_TCP]["logical_bytes"] == 40
    assert merged[LINK_TCP]["wire_bytes"] == 20
    assert merged[LINK_TCP]["ratio"] == pytest.approx(2.0)
    assert merged[LINK_INPROC]["logical_bytes"] == 7


# ---------------------------------------------------------------------------
# TransferSpec


def test_transfer_spec_roundtrip():
    spec = TransferSpec(
        "cascade",
        min_frame_bytes=2048,
        probe_ratio=0.5,
        spill_compression="zlib",
        peer_transfer=False,
        pool_size=4,
        chunk_bytes=1 << 20,
        prefetch_depth=3,
        max_peer_fanout=2,
        fetch_concurrency=8,
    )
    spec.validate()
    d = spec.to_dict()
    assert d == TransferSpec.from_dict(d).to_dict()
    # The peer data-plane knobs ride the same wire dict...
    assert d["peer_transfer"] is False
    assert d["pool_size"] == 4
    assert d["chunk_bytes"] == 1 << 20
    # ...as do the overlap-and-spread knobs (prefetch + replica fan-out)...
    assert d["prefetch_depth"] == 3
    assert d["max_peer_fanout"] == 2
    assert d["fetch_concurrency"] == 8
    # ...and TransferPolicy consumes the compression subset, ignoring them.
    policy = TransferPolicy.from_config(d).to_dict()
    assert policy == {k: d[k] for k in policy}


@pytest.mark.parametrize(
    "kwargs",
    [
        {"compression": "snappy"},
        {"spill_compression": "snappy"},
        {"min_frame_bytes": -1},
        {"probe_ratio": 0.0},
        {"probe_ratio": 1.5},
        {"level": 42},
        {"pool_size": 0},
        {"chunk_bytes": 0},
        {"prefetch_depth": -1},
        {"max_peer_fanout": 0},
        {"fetch_concurrency": 0},
    ],
)
def test_transfer_spec_validation(kwargs):
    with pytest.raises(SpecValidationError):
        TransferSpec(**kwargs).validate()


def test_cluster_and_store_specs_carry_transfer():
    cs = ClusterSpec(n_workers=1, transfer="off")
    cs.validate()
    assert cs.to_dict()["transfer"]["compression"] == "off"
    assert ClusterSpec.from_dict(cs.to_dict()).transfer.compression == "off"

    sc = StoreConfig(name="t", connector="memory", transfer={"compression": "auto"})
    sc.validate()
    assert StoreConfig.from_dict(sc.to_dict()).transfer.compression == "auto"
    # Configs without a transfer spec keep their pre-compression wire shape.
    assert "transfer" not in StoreConfig(name="t2", connector="memory").to_dict()


# ---------------------------------------------------------------------------
# byte paths: SpillCache disk tier + ResultStore publish/fetch


def test_spill_cache_compressed_demote_restore(tmp_path):
    cache = SpillCache(max_bytes=100, spill_dir=str(tmp_path), compress="cascade")
    blob = bytes(128 * 1024) + b"payload-tail" * 32
    assert cache.put("cold", blob)
    assert cache.put("hot", b"y" * 80)  # demotes "cold" to disk, compressed
    assert cache.spilled_keys() == ["cold"]
    # Disk accounting stays in logical bytes: eviction budgets are unchanged.
    assert cache.spilled_bytes == len(blob)
    files = list(tmp_path.iterdir())
    assert files and sum(f.stat().st_size for f in files) < len(blob) // 4

    got = cache.get("cold")  # promotes back
    assert got is not None and got.to_bytes() == blob
    cache.close()


def test_spill_cache_compressed_read_range(tmp_path):
    cache = SpillCache(max_bytes=100, spill_dir=str(tmp_path), compress="cascade")
    blob = bytes(64 * 1024) + b"ABCDEFGH" * 1024
    assert cache.put("k", blob)
    assert cache.put("k2", b"z" * 80)  # demote "k"
    out, offset = bytearray(), 0
    while offset < len(blob):
        view = cache.read_range("k", offset, 10_000)
        assert view is not None and view.nbytes > 0
        out += bytes(view)
        offset += view.nbytes
    assert bytes(out) == blob
    cache.close()


def test_result_store_compresses_cross_process(tmp_path):
    uid = uuid.uuid4().hex[:8]
    rs = ResultStore(
        {
            "name": f"comp-{uid}",
            "connector": {"connector_type": "file", "store_dir": str(tmp_path)},
            "serializer": "default",
            "cache_size": 0,
            "transfer": {"compression": "auto", "min_frame_bytes": 1024},
        }
    )
    assert rs.link_class == LINK_PROCESS
    ledger = TransferLedger()
    blob = np.zeros(500_000, dtype=np.float64).tobytes()
    try:
        ref = rs.publish("t1", blob, ledger=ledger)
        pub = ledger.snapshot()[LINK_PROCESS]
        assert pub["wire_bytes"] < pub["logical_bytes"] == len(blob)
        got = rs.fetch(ref, ledger=ledger)
        assert got is not None and got.to_bytes() == blob
        row = ledger.snapshot()[LINK_PROCESS]
        assert row["transfers"] == 2
        assert row["decompress_ns"] > 0
        # On-disk object is the envelope, not the logical bytes.
        stored = sum(
            f.stat().st_size for f in tmp_path.rglob("*") if f.is_file()
        )
        assert stored < len(blob) // 10
    finally:
        rs.close()


def test_result_store_inproc_link_never_compresses():
    uid = uuid.uuid4().hex[:8]
    rs = ResultStore(
        {
            "name": f"nc-{uid}",
            "connector": {"connector_type": "memory", "segment": f"nc-{uid}"},
            "serializer": "default",
            "cache_size": 0,
            "transfer": {"compression": "auto", "min_frame_bytes": 1024},
        }
    )
    assert rs.link_class == LINK_INPROC
    ledger = TransferLedger()
    blob = bytes(512 * 1024)
    try:
        ref = rs.publish("t1", blob, ledger=ledger)
        got = rs.fetch(ref, ledger=ledger)
        assert got is not None and got.to_bytes() == blob
        row = ledger.snapshot()[LINK_INPROC]
        assert row["wire_bytes"] == row["logical_bytes"]
        assert row["compressed_bytes"] == 0
        assert row["ratio"] == pytest.approx(1.0)
    finally:
        rs.close()


# ---------------------------------------------------------------------------
# cluster surface: worker_stats ledger + transfer_summary


def test_thread_cluster_exposes_transfer_ledger():
    from repro.runtime.client import LocalCluster

    with LocalCluster(
        n_workers=1, inline_result_max=256, transfer={"compression": "auto"}
    ) as cluster:
        with cluster.get_client() as client:
            fut = client.submit(np.zeros, 200_000)
            np.testing.assert_array_equal(fut.result(), np.zeros(200_000))
        stats = cluster.worker_stats()
        assert stats
        for row in stats.values():
            assert "transfer_ledger" in row
        summary = cluster.transfer_summary()
        # Thread workers publish/fetch over the in-memory connector: the
        # inproc link must show zero compression activity.
        for link, row in summary.items():
            assert link in NEVER_COMPRESS_LINKS
            assert row["compressed_bytes"] == 0
            assert row["ratio"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# dequantize_int8 dtype regression (satellite)


@pytest.mark.parametrize("dtype_name", ["bfloat16", "float16", "float32"])
def test_delta_codec_preserves_leaf_dtype(dtype_name):
    jnp = pytest.importorskip("jax.numpy")
    from repro.distributed.compression import CompressedDeltaCodec

    dtype = jnp.dtype(dtype_name)
    base = {"w": np.zeros(512, np.float32)}
    codec = CompressedDeltaCodec(base)
    state = {"w": jnp.asarray(np.linspace(-1, 1, 512, dtype=np.float32), dtype=dtype)}
    decoded = codec.decode(codec.encode(state))
    out = decoded["w"]
    assert np.dtype(out.dtype) == np.dtype(dtype)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(state["w"], np.float32),
        atol=2e-2,
    )


def test_dequantize_int8_dtype_argument():
    jnp = pytest.importorskip("jax.numpy")
    from repro.distributed.compression import dequantize_int8, quantize_int8

    x = jnp.asarray(np.linspace(0, 1, 300, dtype=np.float32), dtype=jnp.bfloat16)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s, x.shape, dtype=x.dtype)
    assert back.dtype == jnp.bfloat16
    assert back.shape == x.shape
