"""StoreExecutor + runtime (scheduler/worker/client) integration tests."""

from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.core import (
    NeverPolicy,
    SizePolicy,
    StoreExecutor,
    TypePolicy,
    extract,
    get_factory,
    is_proxy,
)
from repro.api import Session
from repro.runtime.client import LocalCluster


# -- policies ------------------------------------------------------------------


def test_size_policy():
    pol = SizePolicy(1000)
    assert pol(np.zeros(1000, np.uint8))
    assert not pol(np.zeros(10, np.uint8))
    assert not pol(3)  # scalars never proxy


def test_type_policy():
    pol = TypePolicy(np.ndarray)
    assert pol(np.zeros(1))
    assert not pol([1, 2])


def test_combinators():
    from repro.core import AllPolicy, AnyPolicy

    big_array = AllPolicy(TypePolicy(np.ndarray), SizePolicy(100))
    assert big_array(np.zeros(200, np.uint8))
    assert not big_array(b"x" * 200)
    either = AnyPolicy(TypePolicy(bytes), SizePolicy(100))
    assert either(b"x")
    assert either(np.zeros(200, np.uint8))
    assert not either([1])


# -- StoreExecutor over a stdlib pool --------------------------------------------


def double(x):
    return x * 2


def make_big(n):
    return np.ones(n, np.float64)


def test_store_executor_proxies_large_args(store):
    with ThreadPoolExecutor(2) as pool:
        ex = StoreExecutor(pool, store, should_proxy=SizePolicy(1000))
        arr = np.arange(10_000, dtype=np.float64)
        fut = ex.submit(double, arr)
        out = fut.result()
        np.testing.assert_array_equal(extract(out), arr * 2)


def test_store_executor_small_args_passthrough(store):
    seen = {}

    def probe(x):
        seen["proxied"] = is_proxy(x)
        return x

    with ThreadPoolExecutor(1) as pool:
        ex = StoreExecutor(pool, store, should_proxy=SizePolicy(10**9))
        assert ex.submit(probe, [1, 2]).result() == [1, 2]
        assert seen["proxied"] is False


def test_store_executor_proxies_results(store):
    with ThreadPoolExecutor(1) as pool:
        ex = StoreExecutor(pool, store, should_proxy=SizePolicy(1000))
        out = ex.submit(make_big, 10_000).result()
        assert is_proxy(out)
        assert float(np.asarray(out).sum()) == 10_000.0


def test_store_executor_never_policy(store):
    with ThreadPoolExecutor(1) as pool:
        ex = StoreExecutor(pool, store, should_proxy=NeverPolicy())
        out = ex.submit(make_big, 10_000).result()
        assert not is_proxy(out)


def test_store_executor_one_shot_arg_eviction(store):
    with ThreadPoolExecutor(1) as pool:
        ex = StoreExecutor(pool, store, should_proxy=SizePolicy(100),
                           proxy_results=False)
        arr = np.ones(1000)
        fut = ex.submit(lambda a: float(np.asarray(a).sum()), arr)
        assert fut.result() == 1000.0
        # the argument proxy was one-shot: nothing left in the connector
        time.sleep(0.05)
        assert len(store.connector._data) == 0


def test_store_executor_map(store):
    with ThreadPoolExecutor(2) as pool:
        ex = StoreExecutor(pool, store)
        assert list(ex.map(double, [1, 2, 3])) == [2, 4, 6]


def test_store_executor_ownership_mode(store):
    import gc

    from repro.core import OwnedProxy

    with ThreadPoolExecutor(1) as pool:
        ex = StoreExecutor(pool, store, should_proxy=SizePolicy(100),
                           ownership=True)
        out = ex.submit(make_big, 1000).result()
        assert type(out) is OwnedProxy
        key = get_factory(out).key
        assert store.exists(key)
        del out
        gc.collect()
        assert not store.exists(key)  # result memory auto-managed


# -- runtime: scheduler + workers --------------------------------------------------


def test_submit_gather(cluster):
    with cluster.get_client() as client:
        futs = client.map(double, list(range(10)))
        assert client.gather(futs) == [x * 2 for x in range(10)]


def test_future_dependencies(cluster):
    with cluster.get_client() as client:
        a = client.submit(np.arange, 10)
        b = client.submit(np.sum, a)
        c = client.submit(double, b)
        assert float(c.result()) == 90.0


def test_nested_future_in_containers(cluster):
    with cluster.get_client() as client:
        a = client.submit(double, 10)
        b = client.submit(sum, [a, a])
        assert b.result() == 40


def test_pure_function_caching(cluster):
    calls = []

    def tracked(x):
        calls.append(x)
        return x + 1

    with cluster.get_client() as client:
        f1 = client.submit(tracked, 5)
        assert f1.result() == 6
        f2 = client.submit(tracked, 5)  # same key -> cache hit
        assert f2.result() == 6
        assert f1.key == f2.key
        assert len(calls) == 1


def test_impure_reruns(cluster):
    calls = []

    def tracked(x):
        calls.append(x)
        return x

    with cluster.get_client() as client:
        client.submit(tracked, 1, pure=False).result()
        client.submit(tracked, 1, pure=False).result()
        assert len(calls) == 2


def test_large_result_gather(cluster):
    with cluster.get_client() as client:
        fut = client.submit(make_big, 500_000)  # > inline threshold
        out = fut.result()
        assert out.shape == (500_000,)


def test_task_error_propagates(cluster):
    def boom():
        raise ValueError("intentional")

    with cluster.get_client() as client:
        fut = client.submit(boom, retries=0)
        with pytest.raises(RuntimeError, match="intentional"):
            fut.result(timeout=30)


def test_retries_then_success(cluster):
    # a task that fails twice then succeeds, via a shared mutable cell
    state = {"n": 0}

    def flaky():
        state["n"] += 1
        if state["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    with cluster.get_client() as client:
        assert client.submit(flaky, retries=3, pure=False).result(timeout=30) == "ok"


def test_release_frees_scheduler_state(cluster):
    with cluster.get_client() as client:
        fut = client.submit(double, 21)
        assert fut.result() == 42
        key = fut.key
        client.release([fut])
        deadline = time.monotonic() + 5
        while key in cluster.scheduler.tasks and time.monotonic() < deadline:
            time.sleep(0.02)
        assert key not in cluster.scheduler.tasks


# -- fault tolerance / elasticity ------------------------------------------------


def test_worker_loss_reschedules():
    with LocalCluster(n_workers=2, heartbeat_timeout=1.0) as cluster:
        with cluster.get_client() as client:
            victim = next(iter(cluster.workers))
            cluster.kill_worker(victim)  # heartbeats stop, no deregister
            futs = client.map(double, list(range(20)))
            assert client.gather(futs) == [x * 2 for x in range(20)]
            # scheduler eventually notices the dead worker
            deadline = time.monotonic() + 5
            while victim in cluster.scheduler.workers and time.monotonic() < deadline:
                time.sleep(0.05)
            assert victim not in cluster.scheduler.workers


def test_running_task_survives_worker_death():
    """A task killed mid-flight must re-run elsewhere (lineage recovery)."""
    with LocalCluster(n_workers=2, heartbeat_timeout=0.8) as cluster:
        with cluster.get_client() as client:
            def slow(x):
                time.sleep(0.4)
                return x * 2

            futs = client.map(slow, list(range(6)), pure=False)
            time.sleep(0.1)  # let tasks start
            cluster.kill_worker(next(iter(cluster.workers)))
            assert sorted(client.gather(futs)) == [x * 2 for x in range(6)]


def test_elastic_scale_up():
    with LocalCluster(n_workers=1) as cluster:
        with cluster.get_client() as client:
            futs = client.map(double, list(range(8)))
            cluster.add_worker()
            cluster.add_worker()
            assert client.gather(futs) == [x * 2 for x in range(8)]
            assert len(cluster.scheduler.workers) >= 3


def test_elastic_scale_down():
    with LocalCluster(n_workers=3) as cluster:
        with cluster.get_client() as client:
            wid = next(iter(cluster.workers))
            cluster.remove_worker(wid)  # graceful deregister
            futs = client.map(double, list(range(8)))
            assert client.gather(futs) == [x * 2 for x in range(8)]


def test_straggler_speculation():
    """A pathologically slow worker's task is speculatively duplicated."""
    with LocalCluster(
        n_workers=2, speculation_factor=2.0, speculation_min=0.3
    ) as cluster:
        with cluster.get_client() as client:
            # seed the duration estimate with fast tasks
            client.gather(client.map(double, list(range(6))))

            slow_once = {"done": False}

            def maybe_slow(x):
                # first execution is slow (straggler); the speculative copy
                # on the other worker returns instantly
                if not slow_once["done"]:
                    slow_once["done"] = True
                    time.sleep(3.0)
                return x

            t0 = time.monotonic()
            out = client.submit(maybe_slow, 7, pure=False).result(timeout=30)
            elapsed = time.monotonic() - t0
            assert out == 7
            assert elapsed < 2.5  # won by the speculative duplicate


# -- pass-by-proxy integration (the paper's Fig 1 mechanism) ------------------------
#
# The Session facade is the supported pass-by-proxy surface since the
# legacy-constructor shims were removed; these integration tests drive
# the cluster through it.


def test_session_proxy_results_match_baseline(store):
    with LocalCluster(n_workers=2) as cluster:
        with Session(cluster=cluster, store=store, policy=SizePolicy(10_000)) as s:
            a = s.submit(make_big, 50_000)
            out = a.result()
            assert is_proxy(out)
            assert float(np.asarray(out).sum()) == 50_000.0


def test_session_proxy_dependency_chain(store):
    with LocalCluster(n_workers=2) as cluster:
        with Session(cluster=cluster, store=store, policy=SizePolicy(1000)) as s:
            a = s.submit(make_big, 30_000)
            b = s.submit(lambda x: np.asarray(x) * 2, a, pure=False)
            out = b.result()
            assert float(np.asarray(out)[0]) == 2.0


def test_session_proxy_reduces_scheduler_bytes(store):
    """The paper's central claim, as an invariant: for large payloads the
    proxy path moves far fewer bytes through the centralized scheduler."""
    payload = np.random.default_rng(0).bytes(1_000_000)

    def identity(x):
        return b"ok"

    with LocalCluster(n_workers=1) as cluster:
        with cluster.get_client() as base:
            before = cluster.scheduler.bytes_through()["in_bytes"]
            base.submit(identity, payload, pure=False).result()
            baseline_bytes = (
                cluster.scheduler.bytes_through()["in_bytes"] - before
            )

        with Session(cluster=cluster, store=store, policy=SizePolicy(10_000)) as s:
            before = cluster.scheduler.bytes_through()["in_bytes"]
            s.submit(identity, payload, pure=False).result()
            proxy_bytes = cluster.scheduler.bytes_through()["in_bytes"] - before

    assert baseline_bytes > 1_000_000
    assert proxy_bytes < baseline_bytes / 20


def test_session_proxy_worker_resolves_factory(store):
    """Worker-side code sees the target transparently (no code changes)."""

    def consume(x):
        # task code written for ndarray works with the proxy unchanged
        assert x.shape == (20_000,)
        return float(np.asarray(x).mean())

    arr = np.full(20_000, 3.0)
    with LocalCluster(n_workers=2) as cluster:
        with Session(cluster=cluster, store=store, policy=SizePolicy(1000)) as s:
            assert s.submit(consume, arr, pure=False).result() == 3.0
