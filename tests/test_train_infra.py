"""Training-infrastructure tests: optimizer, checkpoint/restart, data pipeline,
graph tokenization, channels."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import is_proxy, is_resolved
from repro.train.checkpoint import CheckpointManager
from repro.train.data import ProxyPrefetcher, synthetic_batch
from repro.train.optimizer import AdamWConfig, apply_updates, global_norm, init_opt_state, schedule
from repro.train.train_step import init_train_state, make_train_step


# -- optimizer ------------------------------------------------------------------


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lr0 = float(schedule(cfg, jnp.asarray(0)))
    lr_mid = float(schedule(cfg, jnp.asarray(10)))
    lr_end = float(schedule(cfg, jnp.asarray(100)))
    assert lr0 < lr_mid
    assert abs(lr_mid - 1e-3) < 1e-9
    assert abs(lr_end - 1e-4) < 1e-8


def test_global_norm():
    tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(global_norm(tree)) - 5.0) < 1e-6


def test_gradient_clipping_applied():
    params = {"w": jnp.ones(4)}
    opt = init_opt_state(params)
    huge = {"w": jnp.full(4, 1e6)}
    cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0)
    _, _, metrics = apply_updates(cfg, params, huge, opt)
    assert float(metrics["grad_norm"]) > 1.0  # pre-clip norm reported


def test_adamw_quadratic_convergence():
    """AdamW drives a quadratic toward its minimum."""
    params = {"x": jnp.asarray([5.0])}
    opt = init_opt_state(params)
    cfg = AdamWConfig(lr=0.5, warmup_steps=0, weight_decay=0.0,
                      total_steps=200, min_lr_ratio=1.0)
    x_hist = []
    for _ in range(100):
        grads = {"x": 2 * params["x"]}
        params, opt, _ = apply_updates(cfg, params, grads, opt)
        x_hist.append(float(params["x"][0]))
    assert abs(x_hist[-1]) < 0.5


# -- checkpoint/restart (fault tolerance) ---------------------------------------


def test_checkpoint_roundtrip(store, tmp_path):
    cfg = get_smoke_config("qwen2.5-3b")
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    mgr = CheckpointManager(store, str(tmp_path / "index.json"), keep=2)
    mgr.save(3, state, blocking=True)
    assert mgr.latest_step() == 3
    step, restored = mgr.restore()
    assert step == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_restart_resumes_training(store, tmp_path):
    """Full restart loop: train, save, 'crash', restore, keep training."""
    cfg = get_smoke_config("mamba2-130m")
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32)}
    step_fn = jax.jit(make_train_step(cfg, AdamWConfig()))

    state = init_train_state(cfg, jax.random.PRNGKey(0))
    for i in range(3):
        state, _ = step_fn(state, batch)
    mgr = CheckpointManager(store, str(tmp_path / "idx.json"), keep=3)
    mgr.save(3, state, blocking=True)

    # "crash": new manager over the same index + store
    mgr2 = CheckpointManager(store, str(tmp_path / "idx.json"), keep=3)
    step, restored = mgr2.restore()
    assert step == 3
    state2, m2 = step_fn(restored, batch)
    state_ref, m_ref = step_fn(state, batch)
    np.testing.assert_allclose(
        float(m2["loss"]), float(m_ref["loss"]), rtol=1e-6
    )


def test_checkpoint_async_save(store, tmp_path):
    cfg = get_smoke_config("qwen2.5-3b")
    state = init_train_state(cfg, jax.random.PRNGKey(1))
    mgr = CheckpointManager(store, str(tmp_path / "a.json"))
    mgr.save(1, state, blocking=False)  # returns immediately
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_retention_evicts(store, tmp_path):
    mgr = CheckpointManager(store, str(tmp_path / "r.json"), keep=2)
    for s in range(4):
        mgr.save(s, {"w": np.full(100, s)}, blocking=True)
    steps = [m["step"] for m in mgr._index["checkpoints"]]
    assert steps == [2, 3]
    # evicted checkpoints are gone from the connector
    assert mgr.restore(step=0) is None
    got = mgr.restore(step=2)
    assert got is not None and float(np.asarray(got[1]["w"])[0]) == 2.0


def test_lazy_restore_returns_proxies(store, tmp_path):
    mgr = CheckpointManager(store, str(tmp_path / "l.json"))
    state = {"layer": {"w": np.ones((64, 64)), "b": np.zeros(64)}}
    mgr.save(7, state, blocking=True)
    step, lazy = mgr.restore_lazy()
    leaves = jax.tree.leaves(
        lazy, is_leaf=lambda x: is_proxy(x)
    )
    assert all(is_proxy(l) for l in leaves)
    assert all(not is_resolved(l) for l in leaves)
    # resolving one shard does not resolve the others
    np.testing.assert_array_equal(np.asarray(leaves[1]), np.ones((64, 64)))


# -- data pipeline -----------------------------------------------------------------


def test_synthetic_batch_shapes():
    rng = np.random.default_rng(0)
    b = synthetic_batch(rng, 4, 16, 100, extras={"emb": (4, 8, 32)})
    assert b["tokens"].shape == (4, 16) and b["tokens"].dtype == np.int32
    assert b["tokens"].max() < 100
    assert b["emb"].shape == (4, 8, 32)


def test_prefetcher_yields_proxies(store):
    rng = np.random.default_rng(0)

    def make(i):
        return synthetic_batch(rng, 2, 8, 50)

    with ProxyPrefetcher(store, make, depth=2) as pf:
        seen = 0
        for p in pf:
            assert is_proxy(p)
            tokens = p["tokens"]
            assert tokens.shape == (2, 8)
            seen += 1
            if seen >= 4:
                break
    assert seen == 4


def test_prefetcher_overlaps_production(store):
    """While the consumer works, the producer fills the queue (double-buffer)."""
    calls = []

    def make(i):
        calls.append(i)
        return {"x": np.zeros(10)}

    with ProxyPrefetcher(store, make, depth=3) as pf:
        next(pf)
        time.sleep(0.3)  # consumer "computes"; producer should run ahead
        assert len(calls) >= 3


# -- graph / tokenize -----------------------------------------------------------------


def test_tokenize_deterministic():
    from repro.runtime.graph import tokenize

    a = np.arange(100)
    t1 = tokenize(np.sum, [a], [])
    t2 = tokenize(np.sum, [a.copy()], [])
    assert t1 == t2
    t3 = tokenize(np.sum, [a + 1], [])
    assert t1 != t3


def test_tokenize_proxy_uses_token_not_resolution(store):
    from repro.runtime.graph import tokenize

    p = store.proxy(np.arange(1000))
    t = tokenize(np.sum, [p], [])
    assert not is_resolved(p)  # keying a task must not fetch its data
    assert isinstance(t, str) and len(t) > 8


def test_tokenize_distinguishes_functions():
    from repro.runtime.graph import tokenize

    assert tokenize(np.sum, [1], []) != tokenize(np.prod, [1], [])


def test_future_ref_substitution():
    from repro.runtime.graph import FutureRef, find_refs, substitute_refs

    spec = {"a": FutureRef("k1"), "b": [FutureRef("k2"), 3]}
    assert sorted(find_refs(spec)) == ["k1", "k2"]
    out = substitute_refs(spec, {"k1": 10, "k2": 20})
    assert out == {"a": 10, "b": [20, 3]}


# -- channels ---------------------------------------------------------------------------


def test_local_channel_roundtrip():
    from repro.runtime.comm import ChannelClosed, LocalChannel

    ch = LocalChannel("t")
    a, b = ch.endpoint_a(), ch.endpoint_b()
    a.send({"x": np.arange(10)})
    msg = b.recv(timeout=1)
    np.testing.assert_array_equal(msg["x"], np.arange(10))
    assert a.counter.snapshot()["sent_bytes"] == b.counter.snapshot()["recv_bytes"]
    a.close()
    with pytest.raises(ChannelClosed):
        b.recv(timeout=1)


def test_pipe_channel_across_processes():
    import multiprocessing as mp

    from repro.runtime.comm import PipeEndpoint

    parent, child = mp.Pipe()
    pe = PipeEndpoint(parent)

    def child_main(conn):
        ep = PipeEndpoint(conn)
        msg = ep.recv(timeout=10)
        ep.send({"echo": msg["x"] * 2})

    proc = mp.Process(target=child_main, args=(child,))
    proc.start()
    pe.send({"x": np.arange(5)})
    out = pe.recv(timeout=10)
    np.testing.assert_array_equal(out["echo"], np.arange(5) * 2)
    proc.join(timeout=10)
