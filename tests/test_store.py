"""Store-level tests: proxy minting, caching, registry, cross-process resolve."""

from __future__ import annotations

import pickle

import numpy as np

from repro.core import (
    Store,
    get_factory,
    get_or_create_store,
    get_store,
    is_proxy,
    is_resolved,
    unregister_store,
)
from repro.core.connectors import MemoryConnector, FileConnector


def test_put_get_evict(store):
    key = store.put({"a": np.arange(5)})
    out = store.get(key)
    np.testing.assert_array_equal(out["a"], np.arange(5))
    assert store.exists(key)
    store.evict(key)
    assert not store.exists(key)


def test_proxy_roundtrip(store):
    a = np.random.default_rng(0).normal(size=(100,))
    p = store.proxy(a)
    assert is_proxy(p)
    assert not is_resolved(p)
    np.testing.assert_array_equal(np.asarray(p), a)


def test_proxy_idempotent(store):
    p = store.proxy([1, 2])
    assert store.proxy(p) is p  # never proxy a proxy


def test_proxy_batch(store):
    objs = [np.full(10, i) for i in range(4)]
    proxies = store.proxy_batch(objs)
    assert len(proxies) == 4
    for p, o in zip(proxies, objs):
        np.testing.assert_array_equal(np.asarray(p), o)


def test_one_shot_evict_semantics(store):
    p = store.proxy(np.arange(3), evict=True)
    key = get_factory(p).key
    assert store.exists(key)
    _ = p + 0  # first resolution
    assert not store.exists(key)  # evicted after use
    _ = p + 0  # target cached on the proxy itself; still usable


def test_store_cache_serves_repeat_gets(store):
    key = store.put(np.arange(8))
    a = store.get(key)
    b = store.get(key)
    assert a is b  # LRU hit returns the same object
    store.connector.evict(key)
    c = store.get(key)  # still served from cache even after backend evict
    assert c is a


def test_cache_size_zero_disables(tmp_path):
    s = Store("nocache", MemoryConnector(), cache_size=0, register=False)
    key = s.put(np.arange(8))
    assert s.get(key) is not s.get(key)


def test_proxy_from_key(store):
    key = store.put("payload")
    p = store.proxy_from_key(key)
    assert str(p) == "payload"


def test_registry_reuse():
    s = Store("reg-test", MemoryConnector(), register=True)
    try:
        assert get_store("reg-test") is s
        again = get_or_create_store(s.config())
        assert again is s  # same process, same live store
    finally:
        s.close()


def test_get_or_create_opens_fresh():
    unregister_store("fresh-test")
    cfg = {
        "name": "fresh-test",
        "connector": {"connector_type": "memory"},
        "serializer": "default",
        "cache_size": 4,
    }
    s = get_or_create_store(cfg)
    try:
        assert s.name == "fresh-test"
        assert get_store("fresh-test") is s
    finally:
        s.close()


def test_cross_process_style_resolution(tmp_path):
    """Simulates a worker in another address space: the proxy pickles with a
    file-backed store config; a fresh registry entry re-opens the store."""
    s = Store("xproc", FileConnector(str(tmp_path / "x")), register=False)
    arr = np.arange(1000.0)
    p = s.proxy(arr)
    blob = pickle.dumps(p)

    # "other process": wipe this process's registry entry for the store
    unregister_store("xproc")
    q = pickle.loads(blob)
    assert not is_resolved(q)
    np.testing.assert_array_equal(np.asarray(q), arr)
    unregister_store("xproc")


def test_store_config_roundtrip(tmp_path):
    s = Store("cfg-rt", FileConnector(str(tmp_path / "c")), register=False)
    key = s.put([1, 2, 3])
    s2 = Store.from_config(s.config())
    assert s2.get(key) == [1, 2, 3]


def test_missing_get_returns_none(store):
    from repro.core.connectors import Key

    assert store.get(Key.new()) is None


def test_pickle_serializer_store():
    s = Store("pkl", MemoryConnector(), serializer="pickle", register=False)
    key = s.put({"x": np.arange(4)})
    out = s.get(key)
    np.testing.assert_array_equal(out["x"], np.arange(4))
