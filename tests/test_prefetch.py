"""Overlap-and-spread data plane: prefetch pipeline + replica-aware fan-out.

Unit level: SingleFlight dedup semantics; the Prefetcher's pressure
guard (prefetch yields to the pause threshold, never creates it); the
scheduler's freshness-ordered bounded peer list, its re-resolution at
(re)dispatch, holder registration off completions/heartbeats, and the
fan-out admission gate.  Wire level (inproc + tcp): 8 concurrent
same-key fetches on one worker cost exactly one transfer; a busy
replica's in-band reject falls through to the next holder.
"""

from __future__ import annotations

import threading
import time
import uuid

import numpy as np
import pytest

from repro.core.compress import LINK_PEER, TransferLedger
from repro.core.serialize import FrameBundle, deserialize, serialize
from repro.runtime.dataserver import DataServer, PeerWireClient
from repro.runtime.prefetch import Prefetcher, SingleFlight
from repro.runtime.scheduler import (
    GATE_MIN_BYTES,
    Mailbox,
    Scheduler,
    TaskState,
)
from repro.runtime import messages as M
from repro.runtime.transfer import BlobCache
from repro.runtime.worker import ThreadWorker


def _inproc_addr() -> str:
    return f"inproc://pf-{uuid.uuid4().hex[:8]}"


def _wait_for(cond, timeout: float = 5.0) -> None:
    """Poll a server-side counter: on tcp the serving thread accounts a
    moment after the client finishes assembling."""
    deadline = time.monotonic() + timeout
    while not cond() and time.monotonic() < deadline:
        time.sleep(0.01)
    assert cond()


@pytest.fixture(params=["inproc", "tcp"])
def address(request):
    if request.param == "tcp":
        return "tcp://127.0.0.1:0"
    return _inproc_addr()


# ---------------------------------------------------------------------------
# SingleFlight semantics


def test_single_flight_dedups_concurrent_callers():
    flights = SingleFlight()
    calls = []
    gate = threading.Event()

    def fetch():
        calls.append(1)
        gate.wait(5)
        return "bytes"

    results: list = [None] * 8

    def run(i):
        results[i] = flights.run("k", fetch)

    threads = [threading.Thread(target=run, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    time.sleep(0.1)  # let every follower join the in-progress flight
    gate.set()
    for t in threads:
        t.join(timeout=10)
    assert len(calls) == 1  # one fetch, eight consumers
    assert all(r is not None and r[0] == "bytes" for r in results)
    assert sum(1 for r in results if r[1]) == 1  # exactly one leader
    assert flights.inflight() == 0


def test_single_flight_failure_shared_then_retry_fresh():
    flights = SingleFlight()

    def boom():
        raise RuntimeError("fetch failed")

    with pytest.raises(RuntimeError):
        flights.run("k", boom)
    # The failed flight deregistered: a retry leads a fresh fetch.
    result, led, origin = flights.run("k", lambda: 42)
    assert result == 42 and led and origin == "task"


def test_single_flight_reports_leader_origin():
    flights = SingleFlight()
    started = threading.Event()
    release = threading.Event()

    def slow():
        started.set()
        release.wait(5)
        return "b"

    out: dict = {}

    def lead():
        out["lead"] = flights.run("k", slow, origin="prefetch")

    t = threading.Thread(target=lead)
    t.start()
    assert started.wait(5)
    follower: dict = {}

    def follow():
        follower["r"] = flights.run("k", lambda: "never", origin="task")

    f = threading.Thread(target=follow)
    f.start()
    time.sleep(0.05)
    release.set()
    t.join(timeout=5)
    f.join(timeout=5)
    # The executor joined a prefetch-led flight -- that's a prefetch hit.
    assert follower["r"] == ("b", False, "prefetch")


# ---------------------------------------------------------------------------
# worker-level wire dedup (the satellite: 8 fetches -> 1 transfer)


def _bare_worker(**kw) -> ThreadWorker:
    """A worker that is never start()ed: no scheduler, no threads -- just
    the dependency-resolution machinery under test."""
    return ThreadWorker(f"w-{uuid.uuid4().hex[:6]}", scheduler=None, **kw)


def test_concurrent_same_key_fetches_one_wire_transfer(address):
    arr = np.arange(150_000, dtype=np.float64)  # 1.2 MB
    cache = BlobCache(32 << 20)
    cache.put("k", FrameBundle.of(serialize(arr)))
    server_ledger = TransferLedger()
    server = DataServer(cache, address, chunk_bytes=64 * 1024, ledger=server_ledger)
    worker = _bare_worker()
    worker.peer_wire = PeerWireClient(pool_size=4)
    info = {
        "ref": None,
        "nbytes": cache.nbytes_of("k"),
        "locations": ["producer"],
        "peers": [["producer", server.address]],
    }
    results: list = [None] * 8

    def fetch(i):
        results[i] = worker._fetch_dep("k", info, None)

    try:
        threads = [threading.Thread(target=fetch, args=(i,)) for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        for r in results:
            np.testing.assert_array_equal(r, arr)
        # Exactly ONE wire transfer for all eight consumers: the server
        # streamed once, the client dialed once, and the ledger's
        # peer-wire row carries one blob's logical bytes -- not eight.
        _wait_for(lambda: server.serve_count == 1)
        assert worker.peer_wire.snapshot()["peer_wire_fetches"] == 1
        row = server_ledger.snapshot()[LINK_PEER]
        assert row["logical_bytes"] == info["nbytes"]
        assert worker.peer_wire_hits == 1
    finally:
        worker.peer_wire.close()
        server.close()
        worker.cache.close()


# ---------------------------------------------------------------------------
# replica fall-through: miss and busy both try the next holder


def test_fetch_any_falls_through_miss_to_replica(address):
    payload = b"r" * 300_000
    empty = BlobCache(4 << 20)  # first replica evicted the blob
    holder = BlobCache(4 << 20)
    holder.put("k", FrameBundle([memoryview(payload)]))
    s_miss = DataServer(empty, address)
    s_hit = DataServer(
        holder, "tcp://127.0.0.1:0" if address.startswith("tcp") else _inproc_addr()
    )
    client = PeerWireClient()
    try:
        bundle = client.fetch_any([s_miss.address, s_hit.address], "k")
        assert bundle is not None and bundle.to_bytes() == payload
        _wait_for(lambda: s_hit.serve_count == 1)
    finally:
        client.close()
        s_miss.close()
        s_hit.close()


class _GatedCache(BlobCache):
    """Blocks mid-serve on an event: holds a serve slot open so the
    concurrent-serve cap's busy path is deterministic."""

    def __init__(self, payload: bytes, gate: threading.Event, entered: threading.Event):
        super().__init__(max_bytes=4 * len(payload) + 1024)
        self.put("k", FrameBundle([memoryview(payload)]))
        self._gate = gate
        self._entered = entered

    def read_range(self, key, offset, size):
        self._entered.set()
        self._gate.wait(10)
        return super().read_range(key, offset, size)


def test_busy_server_rejects_in_band_and_client_uses_replica(address):
    payload = b"b" * 200_000
    gate, entered = threading.Event(), threading.Event()
    s_busy = DataServer(
        _GatedCache(payload, gate, entered), address, max_concurrent_serves=1
    )
    holder = BlobCache(4 << 20)
    holder.put("k", FrameBundle([memoryview(payload)]))
    s_free = DataServer(
        holder, "tcp://127.0.0.1:0" if address.startswith("tcp") else _inproc_addr()
    )
    blocked_client = PeerWireClient()
    client = PeerWireClient()
    first: list = ["unset"]

    def occupy():
        first[0] = blocked_client.fetch(s_busy.address, "k")

    t = threading.Thread(target=occupy, daemon=True)
    t.start()
    try:
        assert entered.wait(10), "first fetch never reached the serve loop"
        # The saturated replica answers busy in-band; the fetch falls
        # through to the free holder without waiting the stall out.
        t0 = time.monotonic()
        bundle = client.fetch_any([s_busy.address, s_free.address], "k")
        assert bundle is not None and bundle.to_bytes() == payload
        assert time.monotonic() - t0 < 5
        assert s_busy.snapshot()["data_server_busy_rejects"] == 1
        _wait_for(lambda: s_free.serve_count == 1)
        gate.set()
        t.join(timeout=10)
        assert first[0] is not None and first[0].to_bytes() == payload
    finally:
        gate.set()
        blocked_client.close()
        client.close()
        s_busy.close()
        s_free.close()


# ---------------------------------------------------------------------------
# prefetcher: resolves queued deps ahead of execution, pressure-safe


def test_prefetcher_stages_dep_and_counts_hit():
    payload_arr = np.ones(100_000, dtype=np.float64)
    cache = BlobCache(32 << 20)
    cache.put("dep", FrameBundle.of(serialize(payload_arr)))
    server = DataServer(cache, _inproc_addr())
    worker = _bare_worker()
    worker.peer_wire = PeerWireClient()
    info = {
        "ref": None,
        "nbytes": cache.nbytes_of("dep"),
        "locations": ["producer"],
        "peers": [["producer", server.address]],
    }
    with worker._pcv:
        worker._pending.append(
            {"key": "t1", "deps": ["dep"], "dep_info": {"dep": info}, "inline_deps": {}}
        )
        worker._pcv.notify_all()
    pf = Prefetcher(worker, depth=2, flights=worker._flights).start()
    try:
        deadline = time.monotonic() + 10
        while "dep" not in worker.cache and time.monotonic() < deadline:
            time.sleep(0.02)
        assert "dep" in worker.cache, "prefetcher never staged the dep"
        assert pf.snapshot()["prefetch_issued"] == 1
        assert worker._prefetched.get("dep") == info["nbytes"]
        # The executor's resolution is now a cache hit -- and attributed.
        val = worker._fetch_dep("dep", info, None)
        np.testing.assert_array_equal(val, payload_arr)
        assert worker.prefetch_hits == 1
        assert "dep" not in worker._prefetched
    finally:
        pf.stop()
        worker.peer_wire.close()
        server.close()
        worker.cache.close()


def test_prefetch_never_pauses_a_worker(tmp_path):
    """Regression for the pressure contract: a worker sitting just below
    its pause threshold must NOT be pushed over it by prefetch -- the
    prefetcher throttles instead, and the worker stays running."""
    payload = b"d" * 400_000
    cache = BlobCache(4 << 20)
    cache.put("dep", FrameBundle([memoryview(payload)]))
    server = DataServer(cache, _inproc_addr())
    limit = 1_000_000
    worker = _bare_worker(
        memory={
            "limit_bytes": limit,
            "spill_dir": str(tmp_path),
            "pause_fraction": 0.85,
            "target_fraction": 0.6,
        }
    )
    worker.peer_wire = PeerWireClient()
    # Park managed bytes just below the pause threshold (850 KB).
    worker.cache.put("filler", FrameBundle([memoryview(b"f" * 800_000)]))
    assert worker.managed_bytes() < worker._pause_bytes
    info = {
        "ref": None,
        "nbytes": len(payload),  # would land at 1.2 MB -- over the limit
        "locations": ["producer"],
        "peers": [["producer", server.address]],
    }
    with worker._pcv:
        worker._pending.append(
            {"key": "t1", "deps": ["dep"], "dep_info": {"dep": info}, "inline_deps": {}}
        )
        worker._pcv.notify_all()
    pf = Prefetcher(worker, depth=2, flights=worker._flights).start()
    try:
        deadline = time.monotonic() + 2
        while pf.snapshot()["prefetch_throttled"] == 0 and time.monotonic() < deadline:
            time.sleep(0.02)
        assert pf.snapshot()["prefetch_throttled"] > 0
        assert "dep" not in worker.cache  # never fetched
        worker._update_memory_state()
        assert worker.state == "running"
        assert worker.managed_bytes() < worker._pause_bytes
        assert pf.snapshot()["prefetch_issued"] == 0
    finally:
        pf.stop()
        worker.peer_wire.close()
        server.close()
        worker.cache.close()


def test_fetch_concurrency_knob_reaches_worker():
    w = _bare_worker(transfer={"fetch_concurrency": 9, "prefetch_depth": 0})
    assert w._fetch_concurrency == 9
    assert w._prefetch_depth == 0  # 0 disables (no Prefetcher at start())
    w.cache.close()
    w2 = _bare_worker()
    assert w2._fetch_concurrency == 4  # module default preserved
    w2.cache.close()


def test_wasted_prefetch_accounted_on_steal():
    worker = _bare_worker()
    worker._mark_prefetched("dep", 12345)
    with worker._pcv:
        worker._pending.append(
            {"key": "t1", "deps": ["dep"], "dep_info": {}, "inline_deps": {}}
        )
        removed = worker._discard_pending({"t1"})
    assert removed == ["t1"]
    assert worker.prefetch_wasted_bytes == 12345
    assert "dep" not in worker._prefetched
    worker.cache.close()


# ---------------------------------------------------------------------------
# scheduler: holder registration, peer-list ordering, re-resolution, gate


def _sched(**kw) -> Scheduler:
    return Scheduler(**kw)  # never started: unit-level calls only


def _done_task(key: str, nbytes: int) -> TaskState:
    ts = TaskState(key=key, func_blob=b"", args_blob=b"", deps=[])
    ts.state = "done"
    ts.nbytes = nbytes
    ts.ref = f"ref-{key}"
    return ts


def _add_worker(sched: Scheduler, wid: str, addr: str | None = None) -> None:
    sched._register_worker(wid, Mailbox(wid), 1, data_address=addr)


def test_peer_list_is_fresh_bounded_and_origin_last():
    sched = _sched(max_peer_fanout=4)
    for i in range(4):
        _add_worker(sched, f"w{i}", f"tcp://127.0.0.1:1100{i}")
    dts = _done_task("d", 1 << 20)
    sched.tasks["d"] = dts
    for i in range(4):  # registration order: w0 is the origin
        sched._add_holder(dts, sched.workers[f"w{i}"])
    consumer = TaskState(key="c", func_blob=b"", args_blob=b"", deps=["d"])
    sched.tasks["c"] = consumer
    peers = sched._task_payload(consumer)["dep_info"]["d"]["peers"]
    # Newest replicas first, the origin (most reliable fallback) last.
    assert [w for w, _ in peers] == ["w3", "w2", "w1", "w0"]
    # Bounded at max_peer_fanout, always keeping the origin.
    sched.max_peer_fanout = 2
    peers = sched._task_payload(consumer)["dep_info"]["d"]["peers"]
    assert [w for w, _ in peers] == ["w3", "w0"]


def test_peers_reresolved_at_redispatch_excludes_dead_producer():
    sched = _sched()
    _add_worker(sched, "w0", "tcp://127.0.0.1:11000")
    _add_worker(sched, "w1", "tcp://127.0.0.1:11001")
    dts = _done_task("d", 1 << 20)
    sched.tasks["d"] = dts
    sched._add_holder(dts, sched.workers["w0"])
    sched._add_holder(dts, sched.workers["w1"])
    consumer = TaskState(key="c", func_blob=b"", args_blob=b"", deps=["d"])
    sched.tasks["c"] = consumer
    first = sched._task_payload(consumer)["dep_info"]["d"]["peers"]
    assert {w for w, _ in first} == {"w0", "w1"}
    # The producer dies between dispatches (steal / lineage recovery
    # re-readies the task): the payload is rebuilt from CURRENT worker
    # state, so the dead producer is never dialed first -- or at all.
    sched._on_worker_lost("w0", graceful=False)
    second = sched._task_payload(consumer)["dep_info"]["d"]["peers"]
    assert [w for w, _ in second] == ["w1"]


def test_completion_and_heartbeat_register_replica_holders():
    sched = _sched()
    _add_worker(sched, "w0", "tcp://127.0.0.1:11000")
    _add_worker(sched, "w1", "tcp://127.0.0.1:11001")
    dts = _done_task("d", 1 << 20)
    sched.tasks["d"] = dts
    sched._add_holder(dts, sched.workers["w0"])
    # A consumer on w1 finishes, reporting the dep it now caches.
    cts = TaskState(key="c", func_blob=b"", args_blob=b"", deps=["d"])
    cts.state = "running"
    cts.workers = {"w1"}
    sched.tasks["c"] = cts
    sched.workers["w1"].running.add("c")
    sched._on_task_done(
        {"key": "c", "worker": "w1", "nbytes": 10, "cached_deps": ["d"]}
    )
    assert "w1" in dts.locations
    assert dts.holder_seq["w1"] > dts.holder_seq["w0"]  # fresher replica
    # Heartbeat announcements register too -- but only for done tasks.
    _add_worker(sched, "w2", "tcp://127.0.0.1:11002")
    pending = TaskState(key="p", func_blob=b"", args_blob=b"", deps=[])
    sched.tasks["p"] = pending
    sched._handle(
        M.msg(M.HEARTBEAT, worker="w2", cached_keys=["d", "p", "ghost"])
    )
    assert "w2" in dts.locations
    assert "w2" not in pending.locations  # not done: never registered


def test_fanout_gate_defers_then_admits():
    sched = _sched(max_peer_fanout=2)
    for i in range(4):
        _add_worker(sched, f"w{i}", f"tcp://127.0.0.1:1200{i}")
    dts = _done_task("d", GATE_MIN_BYTES)  # exactly gate-sized
    sched.tasks["d"] = dts
    sched._add_holder(dts, sched.workers["w0"])
    consumers = []
    for i in range(3):
        ts = TaskState(key=f"c{i}", func_blob=b"", args_blob=b"", deps=["d"])
        ts.state = "ready"
        sched.tasks[ts.key] = ts
        consumers.append(ts)
    # First max_peer_fanout fetchers are admitted...
    assert not sched._gate_defers(consumers[0], sched.workers["w1"])
    sched._assign(consumers[0], sched.workers["w1"])
    assert not sched._gate_defers(consumers[1], sched.workers["w2"])
    sched._assign(consumers[1], sched.workers["w2"])
    # ...the next one defers (1 holder x fanout 2 already fetching)...
    assert sched._gate_defers(consumers[2], sched.workers["w3"])
    # ...but a worker that already holds the dep is never gated...
    assert not sched._gate_defers(consumers[2], sched.workers["w0"])
    # ...and a finished fetch (or a new holder) reopens admission.
    sched._unassign(sched.workers["w1"], "c0")
    assert not sched._gate_defers(consumers[2], sched.workers["w3"])
    # Sub-gate-size deps never engage the gate at all.
    small = _done_task("s", GATE_MIN_BYTES - 1)
    sched.tasks["s"] = small
    small_consumer = TaskState(key="sc", func_blob=b"", args_blob=b"", deps=["s"])
    sched.tasks["sc"] = small_consumer
    sched._assign(small_consumer, sched.workers["w1"])
    assert ("w1", "sc") not in sched._assigned_fetch_deps


def test_worker_loss_purges_gate_state():
    sched = _sched(max_peer_fanout=1)
    _add_worker(sched, "w0", "tcp://127.0.0.1:11000")
    _add_worker(sched, "w1", "tcp://127.0.0.1:11001")
    dts = _done_task("d", GATE_MIN_BYTES)
    sched.tasks["d"] = dts
    sched._add_holder(dts, sched.workers["w0"])
    ts = TaskState(key="c", func_blob=b"", args_blob=b"", deps=["d"])
    ts.state = "ready"
    sched.tasks["c"] = ts
    sched._assign(ts, sched.workers["w1"])
    assert sched._fetching["d"] == {"w1": 1}
    # The fetcher dies: its gate charge must not hold admission closed.
    sched._on_worker_lost("w1", graceful=False)
    assert "d" not in sched._fetching
    assert not sched._assigned_fetch_deps
