"""Ownership-model tests (Rust-style borrow/move semantics, paper ref [8])."""

from __future__ import annotations

import gc
import pickle

import numpy as np
import pytest

from repro.core import (
    OwnedProxy,
    OwnershipError,
    borrow,
    get_factory,
    is_proxy,
    mut_borrow,
    release,
    transfer,
)
from repro.core.ownership import disown


def test_owned_proxy_resolves(store):
    p = store.owned_proxy(np.arange(4))
    np.testing.assert_array_equal(np.asarray(p), np.arange(4))


def test_del_evicts(store):
    p = store.owned_proxy(np.arange(4))
    key = get_factory(p).key
    assert store.exists(key)
    del p
    gc.collect()
    assert not store.exists(key)


def test_context_manager_evicts(store):
    with store.owned_proxy(np.arange(4)) as p:
        key = get_factory(p).key
        assert store.exists(key)
    assert not store.exists(key)


def test_release_now(store):
    p = store.owned_proxy([1])
    key = get_factory(p).key
    release(p)
    assert not store.exists(key)
    with pytest.raises(OwnershipError):
        release(p)  # moved-from


def test_borrow_many_immutable(store):
    p = store.owned_proxy([1, 2])
    b1, b2 = borrow(p), borrow(p)
    assert b1[0] == 1 and b2[1] == 2
    with pytest.raises(OwnershipError):
        mut_borrow(p)  # immutable borrows active
    del b1, b2
    gc.collect()
    m = mut_borrow(p)  # now fine
    assert m[0] == 1


def test_mut_borrow_exclusive(store):
    p = store.owned_proxy([1])
    m = mut_borrow(p)
    with pytest.raises(OwnershipError):
        mut_borrow(p)
    with pytest.raises(OwnershipError):
        borrow(p)
    del m
    gc.collect()
    assert borrow(p) is not None


def test_transfer_moves_ownership(store):
    p = store.owned_proxy([5])
    key = get_factory(p).key
    q = transfer(p)
    with pytest.raises(OwnershipError):
        borrow(p)  # use-after-move
    # old owner dying must NOT evict (ownership moved)
    del p
    gc.collect()
    assert store.exists(key)
    assert q[0] == 5
    del q
    gc.collect()
    assert not store.exists(key)


def test_transfer_blocked_while_borrowed(store):
    p = store.owned_proxy([1])
    b = borrow(p)
    with pytest.raises(OwnershipError):
        transfer(p)
    del b


def test_pickled_owned_is_borrowed(store):
    """Serialization must not duplicate ownership (double-evict hazard)."""
    p = store.owned_proxy(np.arange(3))
    key = get_factory(p).key
    q = pickle.loads(pickle.dumps(p))
    assert is_proxy(q) and type(q) is not OwnedProxy
    del q
    gc.collect()
    assert store.exists(key)  # borrowed copy dying does not evict
    del p
    gc.collect()
    assert not store.exists(key)


def test_disown_leaks_to_store(store):
    p = store.owned_proxy([9])
    key = get_factory(p).key
    q = disown(p)
    del p, q
    gc.collect()
    assert store.exists(key)  # intentionally leaked


def test_borrow_non_owned_raises(store):
    plain = store.proxy([1])
    with pytest.raises(OwnershipError):
        borrow(plain)
