"""Per-architecture smoke tests on reduced configs (spec deliverable f).

Every assigned arch instantiates a same-family reduced config and runs one
forward + one train step on CPU, asserting output shapes and finite values.
Decoder archs additionally check prefill->decode cache consistency against
the full forward pass.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.models import transformer as tx
from repro.models import whisper as wh
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step

ARCHS = list_archs()
B, S = 2, 32


def full_logits(cfg, params, tokens, *, enc=None, **kw):
    """All-position logits from the hidden-state forward pass."""
    from repro.models.layers import logits_matmul

    if cfg.is_encdec:
        hidden, _ = wh.decode_forward(cfg, params, tokens, enc)
    else:
        hidden, _, _ = tx.forward(cfg, params, tokens, **kw)
    return logits_matmul(cfg, params["embedding"], hidden)


def _batch(cfg, rng: np.random.Generator):
    batch = {
        "tokens": rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = rng.normal(
            size=(B, cfg.num_image_tokens, cfg.d_model)
        ).astype(np.float32)
    if cfg.is_encdec:
        batch["frame_embeds"] = rng.normal(
            size=(B, cfg.encoder_seq, cfg.d_model)
        ).astype(np.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_runs(arch):
    cfg = get_smoke_config(arch)
    rng = np.random.default_rng(0)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, AdamWConfig()))
    batch = _batch(cfg, rng)
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss)
    # roughly at-init cross-entropy: ln(V) +- slack
    assert 0.2 * np.log(cfg.vocab_size) < loss < 3.0 * np.log(cfg.vocab_size)
    # params updated and finite
    flat = jax.tree.leaves(state["params"])
    assert all(bool(jnp.isfinite(x).all()) for x in flat)


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_decreases(arch):
    """Two steps on the same batch must reduce loss (optimizer sanity)."""
    cfg = get_smoke_config(arch)
    rng = np.random.default_rng(1)
    state = init_train_state(cfg, jax.random.PRNGKey(1))
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-3, warmup_steps=0)))
    batch = _batch(cfg, rng)
    losses = []
    for _ in range(5):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_determinism(arch):
    cfg = get_smoke_config(arch)
    rng = np.random.default_rng(2)
    batch = _batch(cfg, rng)
    kw = {}
    enc = None
    if cfg.is_encdec:
        params = wh.init_params(cfg, jax.random.PRNGKey(2))
        enc = wh.encode(cfg, params, jnp.asarray(batch["frame_embeds"]))
    else:
        params = tx.init_params(cfg, jax.random.PRNGKey(2))
        if cfg.family == "vlm":
            kw["patch_embeds"] = jnp.asarray(batch["patch_embeds"])
    logits = full_logits(cfg, params, jnp.asarray(batch["tokens"]), enc=enc, **kw)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    logits2 = full_logits(cfg, params, jnp.asarray(batch["tokens"]), enc=enc, **kw)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits2))


DECODER_ARCHS = [a for a in ARCHS if a != "internvl2-2b"]


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    """KV/SSM-cache correctness: prefill(S) + decode(1) logits must match the
    full forward pass at the corresponding positions."""
    cfg = get_smoke_config(arch)
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    )
    max_len = S + 4

    if cfg.is_encdec:
        params = wh.init_params(cfg, jax.random.PRNGKey(3))
        frames = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        )
        enc = wh.encode(cfg, params, frames)
        full = full_logits(cfg, params, tokens, enc=enc)
        cache = wh.init_cache(cfg, B, max_len, cfg.encoder_seq)
        logits_pre, cache = wh.prefill(cfg, params, tokens[:, :-1], frames, cache)
        step_logits, cache = wh.decode_step(
            cfg, params, cache, tokens[:, -1:],
            jnp.full((B, 1), S - 1, jnp.int32),
        )
    else:
        params = tx.init_params(cfg, jax.random.PRNGKey(3))
        full = full_logits(cfg, params, tokens)
        cache = tx.init_cache(cfg, B, max_len)
        logits_pre, cache = tx.prefill(cfg, params, tokens[:, :-1], cache)
        step_logits, cache = tx.decode_step(
            cfg, params, cache, tokens[:, -1:],
            jnp.full((B, 1), S - 1, jnp.int32),
        )

    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0]), np.asarray(full[:, -1]),
        rtol=2e-2, atol=2e-2,
    )
    # prefill logits must match the full forward at earlier positions too
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, -1]), np.asarray(full[:, -2]),
        rtol=2e-2, atol=2e-2,
    )


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_multi_step_decode_consistency(arch):
    """Decoding tokens one-by-one equals the full forward on the same text."""
    cfg = get_smoke_config(arch)
    rng = np.random.default_rng(4)
    T = 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)).astype(np.int32))

    if cfg.is_encdec:
        params = wh.init_params(cfg, jax.random.PRNGKey(4))
        frames = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        )
        enc = wh.encode(cfg, params, frames)
        full = full_logits(cfg, params, tokens, enc=enc)
        cache = wh.init_cache(cfg, B, T + 2, cfg.encoder_seq)
        _, cache = wh.prefill(cfg, params, tokens[:, :1], frames, cache)
        outs = []
        for t in range(1, T):
            lg, cache = wh.decode_step(
                cfg, params, cache, tokens[:, t : t + 1],
                jnp.full((B, 1), t, jnp.int32),
            )
            outs.append(lg[:, 0])
    else:
        params = tx.init_params(cfg, jax.random.PRNGKey(4))
        full = full_logits(cfg, params, tokens)
        cache = tx.init_cache(cfg, B, T + 2)
        _, cache = tx.prefill(cfg, params, tokens[:, :1], cache)
        outs = []
        for t in range(1, T):
            lg, cache = tx.decode_step(
                cfg, params, cache, tokens[:, t : t + 1],
                jnp.full((B, 1), t, jnp.int32),
            )
            outs.append(lg[:, 0])

    stepwise = jnp.stack(outs, axis=1)  # (B, T-1, V)
    np.testing.assert_allclose(
        np.asarray(stepwise), np.asarray(full[:, 1:]), rtol=3e-2, atol=3e-2
    )


def test_aligned_unrolled_decode_matches_scanned():
    """Serving fast paths (aligned_decode + unrolled layers) must be
    numerically identical to the scanned ragged-scatter path when batch
    lengths are uniform (the aligned-batching precondition)."""
    base = get_smoke_config("granite-20b")
    fast = base.replace(aligned_decode=True, scan_layers=False)
    rng = np.random.default_rng(12)
    T = 10
    tokens = jnp.asarray(rng.integers(0, base.vocab_size, (B, T)).astype(np.int32))
    params = tx.init_params(base, jax.random.PRNGKey(12))

    outs = {}
    for name, cfg in [("scan", base), ("fast", fast)]:
        cache = tx.init_cache(cfg, B, T + 2)
        _, cache = tx.prefill(cfg, params, tokens[:, :4], cache)
        logits = []
        for t in range(4, T):
            lg, cache = tx.decode_step(
                cfg, params, cache, tokens[:, t : t + 1],
                jnp.full((B, 1), t, jnp.int32),
            )
            logits.append(np.asarray(lg[:, 0]))
        outs[name] = np.stack(logits, 1)
    np.testing.assert_allclose(outs["scan"], outs["fast"], rtol=1e-4, atol=1e-4)


def test_moe_dense_vs_ep_equivalence():
    """EP (shard_map all-to-all) and dense MoE paths compute the same thing
    on a single device up to capacity-drop (capacity set high enough)."""
    cfg = get_smoke_config("deepseek-v2-lite-16b")
    rng = np.random.default_rng(5)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32))
    params = tx.init_params(cfg.replace(moe_impl="dense"), jax.random.PRNGKey(5))

    dense_cfg = cfg.replace(moe_impl="dense")
    ep_cfg = cfg.replace(
        moe_impl="ep", moe=cfg.moe.__class__(**{
            **cfg.moe.__dict__, "capacity_factor": 8.0,
        })
    )
    out_dense, _, _ = tx.forward(dense_cfg, params, tokens)
    out_ep, _, _ = tx.forward(ep_cfg, params, tokens)
    np.testing.assert_allclose(
        np.asarray(out_dense), np.asarray(out_ep), rtol=2e-2, atol=2e-2
    )


def test_vlm_patch_embedding_injection():
    cfg = get_smoke_config("internvl2-2b")
    assert cfg.num_image_tokens > 0
    rng = np.random.default_rng(6)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32))
    patches = jnp.asarray(
        rng.normal(size=(B, cfg.num_image_tokens, cfg.d_model)).astype(np.float32)
    )
    params = tx.init_params(cfg, jax.random.PRNGKey(6))
    with_p, _, _ = tx.forward(cfg, params, tokens, patch_embeds=patches)
    without, _, _ = tx.forward(cfg, params, tokens)
    # patches must actually change the result
    assert not np.allclose(np.asarray(with_p), np.asarray(without))


def test_sliding_window_restricts_context():
    """Hymba local layers: a token far outside the window must not affect
    the current position (full-attention layers excluded)."""
    cfg = get_smoke_config("hymba-1.5b").replace(global_layers=())
    rng = np.random.default_rng(7)
    n = cfg.sliding_window * 3
    toks = rng.integers(0, cfg.vocab_size, (1, n)).astype(np.int32)
    toks2 = toks.copy()
    toks2[0, 0] = (toks2[0, 0] + 1) % cfg.vocab_size  # perturb far-past token
    params = tx.init_params(cfg, jax.random.PRNGKey(7))
    a, _, _ = tx.forward(cfg, params, jnp.asarray(toks))
    b, _, _ = tx.forward(cfg, params, jnp.asarray(toks2))
    # SSM heads carry unbounded state, so only *attention* is windowed;
    # final positions still differ through the mamba path -- instead check
    # the perturbation influence decays to numerical noise by the end.
    diff = np.abs(np.asarray(a[0, -1]) - np.asarray(b[0, -1])).max()
    near = np.abs(np.asarray(a[0, 1]) - np.asarray(b[0, 1])).max()
    assert near > diff  # influence decays with distance


def test_mamba_ssd_chunked_vs_decode():
    """SSD chunked scan equals step-by-step recurrence (state-space duality)."""
    from repro.models.ssm import (
        apply_mamba,
        init_mamba,
        init_mamba_cache,
    )

    cfg = get_smoke_config("mamba2-130m")
    rng = np.random.default_rng(8)
    T = 24
    x = jnp.asarray(rng.normal(size=(1, T, cfg.d_model)).astype(np.float32))
    params = init_mamba(cfg, jax.random.PRNGKey(8))
    full, _ = apply_mamba(cfg, params, x)
    cache = init_mamba_cache(cfg, 1)
    outs = []
    for t in range(T):
        y, cache = apply_mamba(cfg, params, x[:, t : t + 1], cache=cache)
        outs.append(y[:, 0])
    stepwise = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(stepwise), np.asarray(full), rtol=2e-2, atol=2e-2
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_param_counts_match_actual(arch):
    """Analytic param_counts (used for MODEL_FLOPS) vs real init tree."""
    cfg = get_smoke_config(arch)
    init = wh.init_params if cfg.is_encdec else tx.init_params
    params = init(cfg, jax.random.PRNGKey(0))
    actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    analytic = cfg.param_counts()["total"]
    # norms/positions aren't in the analytic count; allow 15% slack on the
    # tiny smoke configs (they're negligible at full scale)
    assert abs(actual - analytic) / actual < 0.30


def test_microbatched_train_step_matches_single():
    cfg = get_smoke_config("qwen2.5-3b")
    rng = np.random.default_rng(9)
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (4, S)).astype(np.int32)}
    s1 = init_train_state(cfg, jax.random.PRNGKey(9))
    s2 = jax.tree.map(lambda x: x.copy(), s1)
    step1 = jax.jit(make_train_step(cfg.replace(num_microbatches=1), AdamWConfig()))
    step2 = jax.jit(make_train_step(cfg.replace(num_microbatches=2), AdamWConfig()))
    s1, m1 = step1(s1, batch)
    s2, m2 = step2(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    w1 = jax.tree.leaves(s1["params"])[0]
    w2 = jax.tree.leaves(s2["params"])[0]
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), rtol=1e-4, atol=1e-5)


def test_remat_matches_no_remat():
    cfg = get_smoke_config("qwen2.5-3b")
    rng = np.random.default_rng(10)
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (2, S)).astype(np.int32)}
    s1 = init_train_state(cfg, jax.random.PRNGKey(10))
    s2 = jax.tree.map(lambda x: x.copy(), s1)
    step1 = jax.jit(make_train_step(cfg.replace(remat="none"), AdamWConfig()))
    step2 = jax.jit(make_train_step(cfg.replace(remat="full"), AdamWConfig()))
    _, m1 = step1(s1, batch)
    _, m2 = step2(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)


def test_logits_chunk_matches_full():
    cfg = get_smoke_config("granite-20b")
    rng = np.random.default_rng(11)
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (2, S)).astype(np.int32)}
    state = init_train_state(cfg, jax.random.PRNGKey(11))
    step_full = jax.jit(make_train_step(cfg.replace(logits_chunk=0), AdamWConfig()))
    step_chunk = jax.jit(make_train_step(cfg.replace(logits_chunk=8), AdamWConfig()))
    _, m1 = step_full(jax.tree.map(lambda x: x.copy(), state), batch)
    _, m2 = step_chunk(jax.tree.map(lambda x: x.copy(), state), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
