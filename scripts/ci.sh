#!/usr/bin/env bash
# One CI entrypoint for local runs and the GitHub Actions jobs:
#
#   scripts/ci.sh lint           # ruff check + format check (skips if ruff absent)
#   scripts/ci.sh test           # pytest (-x locally; full failure list when CI=true)
#   scripts/ci.sh smoke          # benchmark regression guards (writes JSON artifacts)
#   scripts/ci.sh smoke-process  # process-backend guards (worker_kind="process", tcp)
#   scripts/ci.sh [all]          # lint + test + smoke, in that order (the default)
#
# Extra arguments after `test`/`all` pass through to pytest.
# (pyproject.toml sets pythonpath=src for pytest; the env var below keeps
# the commands working even under pytest<7 or when invoked from elsewhere.)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

cmd_lint() {
  if command -v ruff >/dev/null 2>&1; then
    ruff check src benchmarks tests
    ruff format --check src benchmarks tests
  elif python -m ruff --version >/dev/null 2>&1; then
    python -m ruff check src benchmarks tests
    python -m ruff format --check src benchmarks tests
  else
    echo "ruff not installed; skipping lint (pip install ruff to enable)" >&2
  fi
}

cmd_test() {
  local args=(-q)
  # Locally, fail fast; in CI report the full failure list.
  if [ "${CI:-}" != "true" ]; then
    args+=(-x)
  fi
  python -m pytest "${args[@]}" "$@"
}

cmd_smoke() {
  # Benchmark regression guards: data-plane invariants (hub-byte reduction,
  # results-by-reference), zero-copy invariants (copies-per-byte-moved
  # <= 1.0 chunked peer / <= 0.1 shm fast path, >= 2x fetch throughput vs
  # the joined-blob baseline, mmap-served spill restores), and
  # control-plane invariants (graph submission <= 2 scheduler msgs/task,
  # >= 2x per-task submit throughput).  JSON lands in artifacts/bench/
  # for the CI artifact upload.
  BENCH_QUICK=1 python -m benchmarks.run --smoke
}

cmd_smoke_process() {
  # Process-backend regression guards: the 512-task fan-out/fan-in graph
  # must hold <= 2 scheduler msgs/task with every message crossing the
  # tcp wire to spawned-interpreter workers, CPU-bound Session.map must
  # hit the core-count-adaptive GIL-escape speedup floor, and the
  # zero-copy invariants must survive the process boundary.  The adaptive
  # compression guard rides along: compressible payloads >= 2x effective
  # tcp throughput vs raw, incompressible payloads < 5% overhead, zero
  # compression activity on the same-host shm link -- and it prints a
  # one-line "# ledger:" summary (wire vs logical bytes, ratio) so the
  # perf trajectory is visible in CI logs, not only in the JSON
  # artifacts.  The continuous-batching serving guard runs here too:
  # at saturation the batched server must hold >= 2x the unbatched
  # throughput with a bounded p99 while the stream broker carries only
  # metadata-sized events.  The peer-data-plane guard closes the set:
  # direct worker-to-worker wire fetches >= 2x the sustained file-store
  # round trip at 8 MiB, a live 2-process-worker fan-in resolving deps
  # over the peer wire with a metadata-only hub at store-only message
  # parity, and clean recovery when the serving worker is killed.  The
  # broadcast guard rides along: a 64 MiB dep fanned out to 8 process
  # workers must spread serving across replicas (producer <= 60% of
  # peer-wire bytes), beat the single-producer path >= 1.5x on mean
  # dep-resolve latency, and show prefetch hits with a reduced
  # queue-to-start wait.  JSON lands in artifacts/bench/ for the CI
  # artifact upload.
  BENCH_QUICK=1 python -m benchmarks.run --smoke-process
}

cmd="${1:-all}"
if [ "$#" -gt 0 ]; then shift; fi
case "$cmd" in
  lint)  cmd_lint ;;
  test)  cmd_test "$@" ;;
  smoke) cmd_smoke ;;
  smoke-process) cmd_smoke_process ;;
  all)   cmd_lint; cmd_test "$@"; cmd_smoke ;;
  *)
    echo "usage: scripts/ci.sh [lint|test|smoke|smoke-process|all] [pytest args...]" >&2
    exit 2
    ;;
esac
