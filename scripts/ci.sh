#!/usr/bin/env bash
# Tier-1 verification: the whole suite + the data-plane smoke benchmark.
# (pyproject.toml sets pythonpath=src for pytest; the env var below keeps
# the commands working even under pytest<7 or when invoked from elsewhere.)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"

# Data-plane regression guard: tiny-payload overheads on the cluster
# backend; fails when scheduler bytes stop dropping or results stop
# passing by reference.
BENCH_QUICK=1 python -m benchmarks.run --smoke
