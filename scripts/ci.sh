#!/usr/bin/env bash
# Tier-1 verification: the whole suite, one command, no manual PYTHONPATH.
# (pyproject.toml sets pythonpath=src for pytest; the env var below keeps
# the command working even under pytest<7 or when invoked from elsewhere.)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
